#!/usr/bin/env python
"""Docs consistency checker (run in CI's docs job and in the test suite).

Three checks, all repo-local and dependency-free:

1. **Intra-repo markdown links** — every relative ``[text](target)`` in
   a tracked ``*.md`` file must point at an existing file/directory; a
   ``#fragment`` on a markdown target must match a heading slug in it.
2. **DESIGN.md § citations** — every ``DESIGN.md §N[.M]`` mention in the
   Python sources must name a numbered section heading that actually
   exists in ``docs/DESIGN.md`` (module docstrings cite sections; stale
   numbers rot fast without this).  GLOSSARY.md's bare ``(§N[.M])``
   pointers are held to the same rule — glossary entries point into
   DESIGN.md by number only, so a renumbering silently strands them.
3. **Core docstring audit** — mirrors the ruff pydocstyle subset enabled
   for ``src/repro/core/`` (D100/D101/D102/D103: module, public class,
   public method, public function docstrings) so the check also runs
   where ruff isn't installed.

Exit code 0 = clean; 1 = problems (each printed with file:line).
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "__pycache__", ".claude", "node_modules",
             "experiments", ".venv", "venv", ".tox", ".eggs", "build",
             "dist", "site-packages", ".pytest_cache", ".ruff_cache"}
# quoted external-repo material — their links point outside this repo
SKIP_FILES = {"SNIPPETS.md", "PAPERS.md"}

_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CITATION = re.compile(r"DESIGN\.md\s*§\s*(\d+(?:\.\d+)*)")
_HEADING_NUM = re.compile(r"^#{1,6}\s+(\d+(?:\.\d+)*)[.\s]", re.M)
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.M)


def _tracked(pattern: str):
    for p in sorted(ROOT.rglob(pattern)):
        if p.name in SKIP_FILES:
            continue
        parts = p.relative_to(ROOT).parts
        if any(d in SKIP_DIRS or d.endswith(".egg-info")
               for d in parts[:-1]):
            continue
        yield p


def _slugify(heading: str) -> str:
    """GitHub-style anchor slug (close enough for intra-repo use)."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def check_markdown_links() -> list:
    problems = []
    for md in _tracked("*.md"):
        text = md.read_text(encoding="utf-8")
        for m in _MD_LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, frag = target.partition("#")
            line = text[:m.start()].count("\n") + 1
            where = f"{md.relative_to(ROOT)}:{line}"
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    problems.append(f"{where}: broken link -> {target}")
                    continue
            else:
                dest = md
            if frag and dest.suffix == ".md" and dest.is_file():
                slugs = {_slugify(h) for _, h in
                         _HEADING.findall(dest.read_text(encoding="utf-8"))}
                if frag.lower() not in slugs:
                    problems.append(
                        f"{where}: missing anchor #{frag} in "
                        f"{dest.relative_to(ROOT)}")
    return problems


def design_sections() -> set:
    """Section numbers declared by docs/DESIGN.md headings."""
    design = ROOT / "docs" / "DESIGN.md"
    if not design.is_file():
        return set()
    return set(_HEADING_NUM.findall(design.read_text(encoding="utf-8")))


def check_design_citations() -> list:
    problems = []
    sections = design_sections()
    if not sections:
        return ["docs/DESIGN.md missing or has no numbered headings"]
    for py in _tracked("*.py"):
        text = py.read_text(encoding="utf-8")
        for m in _CITATION.finditer(text):
            num = m.group(1)
            if num not in sections:
                line = text[:m.start()].count("\n") + 1
                problems.append(
                    f"{py.relative_to(ROOT)}:{line}: cites DESIGN.md "
                    f"§{num} but DESIGN.md has no section {num} "
                    f"(sections: {', '.join(sorted(sections))})")
    return problems


_GLOSSARY_PTR = re.compile(r"§\s*(\d+(?:\.\d+)*)")


def check_glossary_pointers() -> list:
    """GLOSSARY entries cite DESIGN.md by bare section number."""
    problems = []
    sections = design_sections()
    gl = ROOT / "docs" / "GLOSSARY.md"
    if not sections or not gl.is_file():
        return problems
    text = gl.read_text(encoding="utf-8")
    for m in _GLOSSARY_PTR.finditer(text):
        num = m.group(1)
        if num not in sections:
            line = text[:m.start()].count("\n") + 1
            problems.append(
                f"docs/GLOSSARY.md:{line}: points at §{num} but "
                f"DESIGN.md has no section {num}")
    return problems


def check_core_docstrings() -> list:
    problems = []
    core = ROOT / "src" / "repro" / "core"
    for py in sorted(core.glob("*.py")):
        tree = ast.parse(py.read_text(encoding="utf-8"))
        rel = py.relative_to(ROOT)

        def _need(node, kind, name):
            if not ast.get_docstring(node):
                problems.append(
                    f"{rel}:{getattr(node, 'lineno', 1)}: "
                    f"missing docstring on {kind} {name}")

        if not ast.get_docstring(tree):
            problems.append(f"{rel}:1: missing module docstring")
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                if not node.name.startswith("_"):
                    _need(node, "class", node.name)
                for item in node.body:
                    if (isinstance(item, ast.FunctionDef)
                            and not item.name.startswith("_")):
                        _need(item, "method", f"{node.name}.{item.name}")
        for node in tree.body:
            if (isinstance(node, ast.FunctionDef)
                    and not node.name.startswith("_")):
                _need(node, "function", node.name)
    return problems


def main() -> int:
    problems = (check_markdown_links() + check_design_citations()
                + check_glossary_pointers() + check_core_docstrings())
    for p in problems:
        print(p)
    n_md = sum(1 for _ in _tracked("*.md"))
    n_py = sum(1 for _ in _tracked("*.py"))
    print(f"check_docs: scanned {n_md} markdown + {n_py} python files; "
          f"{len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
