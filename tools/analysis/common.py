"""Shared plumbing for the repo's static checkers (DESIGN.md §11).

Loads a package of Python sources into a light semantic model the three
checkers (locks / jit / hostsync) share:

* tokenize-based comment extraction so annotations like
  ``# guarded-by: self._lock`` attach to the line they sit on (or, for
  ``def``/``class`` lines, the comment-only line directly above);
* a class registry with discovered locks (``threading.Lock/RLock/
  Condition`` and the ``named_lock``/``named_condition`` debug
  factories), guarded-attribute declarations, and attribute types
  inferred from annotated ``__init__`` parameters, ``self.x: T``
  annotations, and direct ``self.x = ClassName(...)`` constructions;
* an allowlist (``allowlist.toml``) where every suppression must carry
  a ``reason=`` string.

Everything here is stdlib-only AST work: no JAX, no imports of the
analyzed code.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import io
import pathlib
import re
import tokenize
from typing import Dict, List, Optional, Set, Tuple

# Annotation keywords recognized in comments (see DESIGN.md §11).  One
# comment may carry several annotations separated by ``;;`` (a line can
# only hold one ``#`` comment, so composition happens inside it).
_ANNOT = re.compile(
    r"#?\s*(guarded-by|requires|runs-on|lock-alias|swap-only|jit-ok|"
    r"not-a-sync|memspace|masked|vmem-budget|unit|not-a-transfer)"
    r"\s*:\s*(.*)$")

_LOCK_CTORS = {"Lock", "RLock", "Condition"}
_NAMED_FACTORIES = {"named_lock", "named_condition"}


@dataclasses.dataclass
class Finding:
    """One checker hit, addressable by ``file:qualname:symbol``."""

    checker: str            # locks | jit | hostsync
    file: str               # path relative to the scan root (posix)
    line: int
    qualname: str           # Class.method, function name, or <module>
    symbol: str             # attr / pattern the finding is about
    message: str

    @property
    def site(self) -> str:
        return f"{self.file}:{self.qualname}:{self.symbol}"

    def render(self) -> str:
        return (f"{self.file}:{self.line}: [{self.checker}] "
                f"{self.qualname}: {self.message}")


def parse_annotations(source: str) -> Dict[int, List[Tuple[str, str]]]:
    """Map line -> [(keyword, value), ...] for annotation comments."""
    out: Dict[int, List[Tuple[str, str]]] = {}
    lines = source.splitlines()
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            pairs = []
            for part in tok.string.split(";;"):
                m = _ANNOT.match(part.strip())
                if m:
                    pairs.append((m.group(1), m.group(2).strip()))
            if not pairs:
                continue
            lineno = tok.start[0]
            text = lines[lineno - 1] if lineno <= len(lines) else ""
            # comment-only lines annotate the def/class on the NEXT line
            if text.strip().startswith("#"):
                out.setdefault(lineno + 1, []).extend(pairs)
            else:
                out.setdefault(lineno, []).extend(pairs)
    except tokenize.TokenError:
        pass
    return out


def annotation(mod: "ModuleInfo", line: int, kw: str) -> Optional[str]:
    """Value of annotation ``kw`` on ``line``, or None."""
    for k, v in mod.annotations.get(line, ()):
        if k == kw:
            return v
    return None


def annotation_span(mod: "ModuleInfo", node: ast.AST,
                    kw: str) -> Optional[str]:
    """Like :func:`annotation`, over every line a (multi-line
    statement) node spans."""
    end = getattr(node, "end_lineno", None) or node.lineno
    for line in range(node.lineno, end + 1):
        val = annotation(mod, line, kw)
        if val is not None:
            return val
    return None


def attr_chain(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """``self.state.lock`` -> ('self', 'state', 'lock'); None if not a
    pure Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


@dataclasses.dataclass
class FunctionInfo:
    """A method or module-level function plus its thread contract."""

    name: str
    qualname: str
    node: ast.AST           # FunctionDef / AsyncFunctionDef
    module: str             # rel path
    cls: Optional[str]
    requires_raw: List[str] = dataclasses.field(default_factory=list)
    runs_on: Optional[str] = None
    runs_on_explicit: bool = False
    requires: Set[str] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class ClassInfo:
    """Locks, guarded attrs, and attribute types of one class."""

    name: str
    module: str
    node: ast.ClassDef
    locks: Dict[str, str] = dataclasses.field(default_factory=dict)
    guarded_raw: Dict[str, List[str]] = dataclasses.field(
        default_factory=dict)
    guarded: Dict[str, Set[str]] = dataclasses.field(default_factory=dict)
    swap_only: Set[str] = dataclasses.field(default_factory=set)
    attr_types: Dict[str, str] = dataclasses.field(default_factory=dict)
    methods: Dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict)
    class_requires_raw: List[str] = dataclasses.field(default_factory=list)
    class_requires: Set[str] = dataclasses.field(default_factory=set)
    jit_attrs: Set[str] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file."""

    rel: str
    tree: ast.Module
    annotations: Dict[int, List[Tuple[str, str]]]
    import_alias: Dict[str, str] = dataclasses.field(default_factory=dict)
    from_imports: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)   # local name -> (module, original)
    functions: Dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict)
    classes: List[str] = dataclasses.field(default_factory=list)


def _split_alts(value: str) -> List[str]:
    return [a.strip() for a in value.split("|") if a.strip()]


def _annotation_names(node: ast.AST, known: Set[str]) -> Optional[str]:
    """First known class name mentioned in a type annotation."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in known:
            return sub.id
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str) \
                and sub.value in known:
            return sub.value
    return None


class Package:
    """All modules under a root directory, as one semantic model."""

    def __init__(self) -> None:
        self.modules: Dict[str, ModuleInfo] = {}
        self.classes: Dict[str, ClassInfo] = {}
        self.config_errors: List[Finding] = []

    # -- loading ---------------------------------------------------
    @classmethod
    def load(cls, root: pathlib.Path,
             override: Optional[Dict[str, str]] = None) -> "Package":
        """Parse every ``*.py`` under ``root``.

        ``override`` maps rel paths to replacement source text — used
        by the seeded-violation smoke test to break an annotation
        in-memory without touching the tree.
        """
        pkg = cls()
        root = pathlib.Path(root)
        for path in sorted(root.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if "__pycache__" in rel:
                continue
            source = (override or {}).get(rel)
            if source is None:
                source = path.read_text(encoding="utf-8")
            try:
                tree = ast.parse(source)
            except SyntaxError as e:
                pkg.config_errors.append(Finding(
                    "common", rel, e.lineno or 1, "<module>", "syntax",
                    f"cannot parse: {e.msg}"))
                continue
            mod = ModuleInfo(rel=rel, tree=tree,
                             annotations=parse_annotations(source))
            pkg.modules[rel] = mod
        pkg._collect()
        pkg._resolve()
        return pkg

    # -- pass 1: collect classes / locks / annotations -------------
    def _collect(self) -> None:
        for mod in self.modules.values():
            for node in mod.tree.body:
                if isinstance(node, (ast.Import,)):
                    for al in node.names:
                        mod.import_alias[al.asname or al.name] = al.name
                elif isinstance(node, ast.ImportFrom):
                    src = node.module or ""
                    for al in node.names:
                        mod.from_imports[al.asname or al.name] = (
                            src, al.name)
                elif isinstance(node, ast.ClassDef):
                    self._collect_class(mod, node)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    fi = self._make_function(mod, node, None)
                    mod.functions[node.name] = fi
        # attr types need the full class registry; second sweep
        known = set(self.classes)
        for mod in self.modules.values():
            for cname in mod.classes:
                self._infer_attr_types(mod, self.classes[cname], known)

    def _make_function(self, mod: ModuleInfo, node, cname) -> FunctionInfo:
        qual = f"{cname}.{node.name}" if cname else node.name
        fi = FunctionInfo(name=node.name, qualname=qual, node=node,
                          module=mod.rel, cls=cname)
        for kw, val in mod.annotations.get(node.lineno, ()):
            if kw == "requires":
                fi.requires_raw = _split_alts(val)
            elif kw == "runs-on":
                fi.runs_on = val
                fi.runs_on_explicit = True
        return fi

    def _collect_class(self, mod: ModuleInfo, node: ast.ClassDef) -> None:
        ci = ClassInfo(name=node.name, module=mod.rel, node=node)
        self.classes[node.name] = ci
        mod.classes.append(node.name)
        req = annotation(mod, node.lineno, "requires")
        if req is not None:
            ci.class_requires_raw = _split_alts(req)
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                ci.methods[item.name] = self._make_function(
                    mod, item, node.name)
                for stmt in ast.walk(item):
                    self._note_self_assign(mod, ci, stmt)
            elif isinstance(item, (ast.Assign, ast.AnnAssign)):
                self._note_self_assign(mod, ci, item, class_level=True)

    def _note_self_assign(self, mod: ModuleInfo, ci: ClassInfo,
                          stmt: ast.AST, class_level: bool = False) -> None:
        """Record locks / guarded-by / swap-only on ``self.X = ...``."""
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign):
            targets, value = [stmt.target], stmt.value
        else:
            return
        for tgt in targets:
            if class_level and isinstance(tgt, ast.Name):
                attr = tgt.id
            else:
                chain = attr_chain(tgt)
                if chain is None or len(chain) != 2 or chain[0] != "self":
                    continue
                attr = chain[1]
            for kw, val in mod.annotations.get(stmt.lineno, ()):
                if kw == "guarded-by":
                    ci.guarded_raw.setdefault(attr, _split_alts(val))
                elif kw == "swap-only":
                    ci.swap_only.add(attr)
                elif kw == "lock-alias":
                    ci.locks[attr] = val
            self._note_lock_ctor(mod, ci, attr, value, stmt.lineno)

    def _note_lock_ctor(self, mod: ModuleInfo, ci: ClassInfo, attr: str,
                        value, lineno: int) -> None:
        if not isinstance(value, ast.Call):
            return
        fchain = attr_chain(value.func)
        if not fchain:
            return
        tail = fchain[-1]
        canonical = f"{ci.name}.{attr}"
        if tail in _NAMED_FACTORIES:
            ci.locks.setdefault(attr, canonical)
            arg = value.args[0] if value.args else None
            name = arg.value if isinstance(arg, ast.Constant) else None
            if name != canonical:
                self.config_errors.append(Finding(
                    "locks", mod.rel, lineno, canonical, attr,
                    f"{tail}() name {name!r} must be the canonical lock "
                    f"id {canonical!r} (static/runtime identity sync)"))
        elif tail in _LOCK_CTORS and (
                len(fchain) == 1 or fchain[0] in ("threading",)):
            ci.locks.setdefault(attr, canonical)
        elif tail == "jit" or (tail == "partial" and value.args
                               and attr_chain(value.args[0])
                               and attr_chain(value.args[0])[-1] == "jit"):
            ci.jit_attrs.add(attr)

    def _infer_attr_types(self, mod: ModuleInfo, ci: ClassInfo,
                          known: Set[str]) -> None:
        for meth in ci.methods.values():
            node = meth.node
            param_types: Dict[str, str] = {}
            args = node.args
            for a in list(args.posonlyargs) + list(args.args) \
                    + list(args.kwonlyargs):
                if a.annotation is not None:
                    t = _annotation_names(a.annotation, known)
                    if t:
                        param_types[a.arg] = t
            for stmt in ast.walk(node):
                tgt = value = annot = None
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt, value = stmt.targets[0], stmt.value
                elif isinstance(stmt, ast.AnnAssign):
                    tgt, value, annot = stmt.target, stmt.value, \
                        stmt.annotation
                else:
                    continue
                chain = attr_chain(tgt)
                if chain is None or len(chain) != 2 or chain[0] != "self":
                    continue
                attr = chain[1]
                if annot is not None:
                    t = _annotation_names(annot, known)
                    if t:
                        ci.attr_types.setdefault(attr, t)
                if isinstance(value, ast.Name) \
                        and value.id in param_types:
                    ci.attr_types.setdefault(attr, param_types[value.id])
                elif isinstance(value, ast.Call):
                    fchain = attr_chain(value.func)
                    if fchain and fchain[-1] in known:
                        ci.attr_types.setdefault(attr, fchain[-1])

    # -- pass 2: resolve annotation alternatives -------------------
    def _resolve_alt(self, ci: ClassInfo, alt: str, lineno: int) -> \
            Optional[str]:
        """``self.X`` -> lock id via the declaring class; dotted names
        pass through as canonical lock ids; bare tokens are threads."""
        if alt.startswith("self."):
            attr = alt[len("self."):]
            lock = ci.locks.get(attr)
            if lock is None:
                self.config_errors.append(Finding(
                    "locks", ci.module, lineno, ci.name, attr,
                    f"annotation names {alt!r} but {ci.name}.{attr} is "
                    f"not a discovered lock"))
                return None
            return lock
        return alt  # "Class.attr" lock id or bare thread token

    def _resolve(self) -> None:
        for ci in self.classes.values():
            ln = ci.node.lineno
            ci.class_requires = {
                r for a in ci.class_requires_raw
                if (r := self._resolve_alt(ci, a, ln)) is not None}
            for attr, alts in ci.guarded_raw.items():
                ci.guarded[attr] = {
                    r for a in alts
                    if (r := self._resolve_alt(ci, a, ln)) is not None}
            for meth in ci.methods.values():
                meth.requires = {
                    r for a in meth.requires_raw
                    if (r := self._resolve_alt(
                        ci, a, meth.node.lineno)) is not None}
                if not meth.requires and ci.class_requires \
                        and meth.name not in ("__init__", "__post_init__"):
                    meth.requires = set(ci.class_requires)
        self._propagate_runs_on()

    # -- runs-on propagation through intra-class private calls -----
    def _propagate_runs_on(self) -> None:
        for ci in self.classes.values():
            callers: Dict[str, Set[str]] = {m: set() for m in ci.methods}
            for name, meth in ci.methods.items():
                for sub in ast.walk(meth.node):
                    if isinstance(sub, ast.Call):
                        chain = attr_chain(sub.func)
                        if chain and len(chain) == 2 \
                                and chain[0] == "self" \
                                and chain[1] in ci.methods:
                            callers[chain[1]].add(name)
            changed = True
            while changed:
                changed = False
                for name, meth in ci.methods.items():
                    if meth.runs_on is not None or meth.requires:
                        continue
                    if not name.startswith("_") or name.startswith("__"):
                        continue
                    cs = callers[name] - {name}
                    if not cs:
                        continue
                    tokens = {ci.methods[c].runs_on for c in cs}
                    if len(tokens) == 1 and None not in tokens:
                        tok = tokens.pop()
                        if tok != "any":
                            meth.runs_on = tok
                            changed = True

    # -- shared resolution helpers ---------------------------------
    def lock_of_chain(self, ci: Optional[ClassInfo],
                      chain: Tuple[str, ...],
                      local_types: Dict[str, str]) -> Optional[str]:
        """Resolve an expression chain to a canonical lock id."""
        if not chain:
            return None
        if chain[0] == "self" and ci is not None:
            if len(chain) == 2:
                return ci.locks.get(chain[1])
            if len(chain) == 3:
                t = ci.attr_types.get(chain[1])
                if t and t in self.classes:
                    return self.classes[t].locks.get(chain[2])
            return None
        t = local_types.get(chain[0])
        if t and t in self.classes:
            if len(chain) == 2:
                return self.classes[t].locks.get(chain[1])
        return None

    def class_of_chain(self, ci: Optional[ClassInfo],
                       chain: Tuple[str, ...],
                       local_types: Dict[str, str]) -> \
            Optional[Tuple[str, str]]:
        """Resolve ``<obj>.attr`` to (ClassName, attr) when typed."""
        if len(chain) < 2:
            return None
        if chain[0] == "self" and ci is not None:
            if len(chain) == 2:
                return (ci.name, chain[1])
            if len(chain) == 3:
                t = ci.attr_types.get(chain[1])
                if t:
                    return (t, chain[2])
            return None
        t = local_types.get(chain[0])
        if t and len(chain) == 2:
            return (t, chain[1])
        return None

    def local_types_for(self, fi: FunctionInfo) -> Dict[str, str]:
        """Param annotations + simple ``x = self.attr`` aliases."""
        known = set(self.classes)
        out: Dict[str, str] = {}
        node = fi.node
        args = node.args
        for a in list(args.posonlyargs) + list(args.args) \
                + list(args.kwonlyargs):
            if a.annotation is not None:
                t = _annotation_names(a.annotation, known)
                if t:
                    out[a.arg] = t
        ci = self.classes.get(fi.cls) if fi.cls else None
        for stmt in ast.walk(node):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                chain = attr_chain(stmt.value)
                if chain and chain[0] == "self" and len(chain) == 2 \
                        and ci is not None:
                    t = ci.attr_types.get(chain[1])
                    if t:
                        out[stmt.targets[0].id] = t
        return out

    def resolve_callee(self, mod: ModuleInfo, fi: FunctionInfo,
                       call: ast.Call,
                       local_types: Dict[str, str]) -> \
            Optional[FunctionInfo]:
        """Resolve a call to a FunctionInfo inside this package."""
        chain = attr_chain(call.func)
        if chain is None:
            return None
        if len(chain) == 1:
            name = chain[0]
            if name in mod.functions:
                return mod.functions[name]
            imp = mod.from_imports.get(name)
            if imp:
                for m in self.modules.values():
                    if imp[1] in m.functions and (
                            m.rel.endswith(imp[0].lstrip(".")
                                           .replace(".", "/") + ".py")
                            or imp[0].lstrip(".") == ""):
                        return m.functions[imp[1]]
            return None
        owner = self.class_of_chain(
            self.classes.get(fi.cls) if fi.cls else None,
            chain, local_types)
        if owner is None:
            return None
        cname, meth = owner
        ci = self.classes.get(cname)
        if ci and meth in ci.methods:
            return ci.methods[meth]
        return None

    def all_functions(self) -> List[FunctionInfo]:
        out: List[FunctionInfo] = []
        for mod in self.modules.values():
            out.extend(mod.functions.values())
            for cname in mod.classes:
                out.extend(self.classes[cname].methods.values())
        return out


# ---------------------------------------------------------------------
# Allowlist
# ---------------------------------------------------------------------

_TOML_KV = re.compile(r'^(\w+)\s*=\s*"((?:[^"\\]|\\.)*)"\s*$')


def _parse_toml_subset(text: str) -> List[Dict[str, str]]:
    """``[[allow]]`` tables with string values — the only TOML this
    repo's allowlist needs, parsed without tomllib (py3.10 support)."""
    entries: List[Dict[str, str]] = []
    current: Optional[Dict[str, str]] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line == "[[allow]]":
            current = {}
            entries.append(current)
            continue
        m = _TOML_KV.match(line)
        if m and current is not None:
            current[m.group(1)] = m.group(2).replace('\\"', '"')
    return entries


@dataclasses.dataclass
class AllowEntry:
    """One suppression; ``site`` may be an fnmatch glob."""

    checker: str
    site: str
    reason: str
    kind: str = ""
    used: int = 0


class Allowlist:
    """Suppressions that must each carry a reason string."""

    def __init__(self, entries: List[AllowEntry],
                 errors: List[str]) -> None:
        self.entries = entries
        self.errors = errors

    @classmethod
    def load(cls, path: Optional[pathlib.Path]) -> "Allowlist":
        if path is None or not pathlib.Path(path).is_file():
            return cls([], [])
        text = pathlib.Path(path).read_text(encoding="utf-8")
        try:
            import tomllib
            raw = tomllib.loads(text).get("allow", [])
        except ModuleNotFoundError:
            raw = _parse_toml_subset(text)
        entries, errors = [], []
        for i, e in enumerate(raw):
            if not e.get("reason", "").strip():
                errors.append(
                    f"allowlist entry #{i + 1} ({e.get('site', '?')}) "
                    f"has no reason= — every suppression must say why")
                continue
            entries.append(AllowEntry(
                checker=e.get("checker", "*"), site=e.get("site", ""),
                reason=e["reason"], kind=e.get("kind", "")))
        return cls(entries, errors)

    def match(self, f: Finding) -> Optional[AllowEntry]:
        for e in self.entries:
            if e.checker not in ("*", f.checker):
                continue
            if fnmatch.fnmatchcase(f.site, e.site):
                e.used += 1
                return e
        return None

    def apply(self, findings: List[Finding]) -> \
            Tuple[List[Finding], List[Finding]]:
        """Split into (surviving, suppressed)."""
        kept, suppressed = [], []
        for f in findings:
            (suppressed if self.match(f) else kept).append(f)
        return kept, suppressed

    def unused(self) -> List[AllowEntry]:
        return [e for e in self.entries if e.used == 0]
