"""CLI driver: ``python -m tools.analysis [--strict] [--json]
[--only CHECKER] [--sarif PATH]``."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.analysis import (CHECKERS, DEFAULT_ALLOWLIST, DEFAULT_SRC,
                            Result, run)

_SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/"
                 "sarif-spec/master/Schemata/sarif-schema-2.1.0.json")


def to_sarif(res: Result, src_prefix: str = "src/repro") -> dict:
    """Render a run as minimal SARIF 2.1.0 for GitHub code scanning."""
    rules = {}
    results = []
    for f in list(res.findings) + list(res.config_errors):
        rule_id = f"{f.checker}/{f.symbol}" if f.symbol else f.checker
        rules.setdefault(rule_id, {
            "id": rule_id,
            "shortDescription": {"text": f"{f.checker}: {f.symbol}"},
        })
        results.append({
            "ruleId": rule_id,
            "level": "error",
            "message": {"text": f"{f.qualname}: {f.message}"},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": f"{src_prefix}/{f.file}",
                        "uriBaseId": "ROOT",
                    },
                    "region": {"startLine": max(1, f.line)},
                },
            }],
        })
    return {
        "$schema": _SARIF_SCHEMA,
        "version": "2.1.0",
        "runs": [{
            "tool": {
                "driver": {
                    "name": "tools.analysis",
                    "informationUri":
                        "https://example.invalid/tools/analysis",
                    "rules": sorted(rules.values(),
                                    key=lambda r: r["id"]),
                },
            },
            "results": results,
        }],
    }


def main(argv=None) -> int:
    """Run the checkers; exit 0 only on a clean tree."""
    ap = argparse.ArgumentParser(prog="python -m tools.analysis")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on unused allowlist entries")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (counts + findings)")
    ap.add_argument("--only", action="append", choices=CHECKERS,
                    metavar="CHECKER",
                    help="run only this checker (repeatable); unused-"
                         "allowlist strictness applies to it alone")
    ap.add_argument("--sarif", type=pathlib.Path, metavar="PATH",
                    help="also write findings as SARIF 2.1.0 to PATH")
    ap.add_argument("--root", type=pathlib.Path, default=DEFAULT_SRC,
                    help="source tree to analyze")
    ap.add_argument("--allowlist", type=pathlib.Path,
                    default=DEFAULT_ALLOWLIST)
    args = ap.parse_args(argv)

    res = run(root=args.root, allowlist=args.allowlist,
              only=tuple(args.only) if args.only else None)
    if args.sarif is not None:
        args.sarif.parent.mkdir(parents=True, exist_ok=True)
        args.sarif.write_text(json.dumps(to_sarif(res), indent=1),
                              encoding="utf-8")
    if args.as_json:
        payload = {
            "counts": res.counts,
            "findings": [f.render() for f in res.findings],
            "config_errors": [f.render() for f in res.config_errors],
            "allow_errors": res.allow_errors,
            "unused_allowlist": [e.site for e in res.unused],
            "ok": res.ok(strict=args.strict),
        }
        print(json.dumps(payload, indent=1))
        return 0 if res.ok(strict=args.strict) else 1

    for f in res.config_errors:
        print(f"CONFIG {f.render()}")
    for msg in res.allow_errors:
        print(f"ALLOWLIST {msg}")
    for f in res.findings:
        print(f.render())
    if args.strict:
        for e in res.unused:
            print(f"UNUSED allowlist entry: [{e.checker}] {e.site} "
                  f"— the code it suppressed is gone; delete it")
    c = res.counts
    status = "clean" if res.ok(strict=args.strict) else "FAILED"
    print(f"tools.analysis: {status} — {c['findings']} finding(s), "
          f"{c['suppressions']} suppressed "
          f"({c['syncs_allowed']} allowed syncs, "
          f"{c['budgeted_transfers']} budgeted transfers), "
          f"{c['named_locks']} locks / {c['guarded_attrs']} guarded "
          f"attrs / {c['jit_sites']} jit sites / "
          f"{c['hot_path_functions']} hot-path functions / "
          f"{c['memspace_attrs']} memspace attrs / "
          f"{c['kernels_checked']} kernels ({c['vmem_budgets']} "
          f"budgeted) / {c['unit_fields']}+{c['unit_functions']} "
          f"unit-annotated fields+functions")
    return 0 if res.ok(strict=args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
