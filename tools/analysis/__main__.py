"""CLI driver: ``python -m tools.analysis [--strict] [--json]``."""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from tools.analysis import DEFAULT_ALLOWLIST, DEFAULT_SRC, run


def main(argv=None) -> int:
    """Run the three checkers; exit 0 only on a clean tree."""
    ap = argparse.ArgumentParser(prog="python -m tools.analysis")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on unused allowlist entries")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output (counts + findings)")
    ap.add_argument("--root", type=pathlib.Path, default=DEFAULT_SRC,
                    help="source tree to analyze")
    ap.add_argument("--allowlist", type=pathlib.Path,
                    default=DEFAULT_ALLOWLIST)
    args = ap.parse_args(argv)

    res = run(root=args.root, allowlist=args.allowlist)
    if args.as_json:
        payload = {
            "counts": res.counts,
            "findings": [f.render() for f in res.findings],
            "config_errors": [f.render() for f in res.config_errors],
            "allow_errors": res.allow_errors,
            "unused_allowlist": [e.site for e in res.unused],
            "ok": res.ok(strict=args.strict),
        }
        print(json.dumps(payload, indent=1))
        return 0 if res.ok(strict=args.strict) else 1

    for f in res.config_errors:
        print(f"CONFIG {f.render()}")
    for msg in res.allow_errors:
        print(f"ALLOWLIST {msg}")
    for f in res.findings:
        print(f.render())
    if args.strict:
        for e in res.unused:
            print(f"UNUSED allowlist entry: [{e.checker}] {e.site} "
                  f"— the code it suppressed is gone; delete it")
    c = res.counts
    status = "clean" if res.ok(strict=args.strict) else "FAILED"
    print(f"tools.analysis: {status} — {c['findings']} finding(s), "
          f"{c['suppressions']} suppressed "
          f"({c['syncs_allowed']} allowed syncs), "
          f"{c['named_locks']} locks / {c['guarded_attrs']} guarded "
          f"attrs / {c['jit_sites']} jit sites / "
          f"{c['hot_path_functions']} hot-path functions")
    return 0 if res.ok(strict=args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
