"""Pallas kernel contracts over ``src/repro/kernels/*`` (DESIGN.md §11.5).

Four statically-checkable contracts per kernel package:

* **triple** — every package keeps its ``kernel.py`` / ``ops.py`` /
  ``ref.py`` triple, and the package is cross-referenced by the
  interpret-mode parity tests (``tests/test_kernels.py``), so a kernel
  can't land without a reference implementation and an A/B test.
* **grid-arity** — every BlockSpec index lambda takes exactly
  ``len(grid)`` arguments (plus ``num_scalar_prefetch`` refs under a
  ``PrefetchScalarGridSpec``); a silent arity mismatch is a tracing
  error only at call time, on hardware.
* **blockspec-divide** — block shapes must divide the operand shapes
  they tile.  Shapes are tracked symbolically (``B, S, H, D = x.shape``
  unpacks, ``reshape``/``transpose``/``swapaxes`` chains) and
  divisibility is discharged by ``assert X % b == 0`` facts in the
  wrapper; a ``# masked: <reason>`` note on the BlockSpec line opts a
  deliberately ragged tiling out.
* **vmem-budget** — a static footprint estimate (sum of block + scratch
  tiles at the production point named by the annotation's bindings)
  must fit the wrapper's ``# vmem-budget: <MiB> MiB @ sym=val ...``
  declaration, so future multi-page / double-buffered blocks can't
  silently blow VMEM.  Operand tiles are costed at 4 bytes/element
  (f32 upper bound); scratch uses its declared dtype.
"""
from __future__ import annotations

import ast
import pathlib
import re
from typing import Dict, List, Optional, Tuple

from tools.analysis.common import (Finding, ModuleInfo, Package,
                                   annotation_span, attr_chain)

_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "bfloat16": 2,
                "float16": 2, "int8": 1, "uint8": 1, "float64": 8,
                "int64": 8, "bool_": 1}
_OPERAND_BYTES = 4          # f32 upper bound for in/out tiles
_SHAPE_METHODS_PASS = {"astype"}


def _norm(e: ast.AST) -> str:
    """Normalized source text of an expression (symbolic dim identity)."""
    return ast.unparse(e)


class _BudgetSyntax(ValueError):
    pass


def parse_budget(text: str) -> Tuple[float, Dict[str, int]]:
    """``2.0 MiB @ bq=512 Dh=128`` -> (MiB, symbol bindings)."""
    text = text.strip()
    m = re.match(r"^([0-9.]+)\s*MiB\s*(?:@\s*(.*))?$", text)
    if not m:
        raise _BudgetSyntax(
            f"vmem-budget must be '<MiB> MiB @ sym=val ...', got {text!r}")
    binds: Dict[str, int] = {}
    for tok in (m.group(2) or "").split():
        if "=" not in tok:
            raise _BudgetSyntax(f"bad binding {tok!r} in vmem-budget")
        name, _, val = tok.partition("=")
        binds[name] = int(val)
    return float(m.group(1)), binds


class _Wrapper:
    """Shape/divisibility context of one kernel wrapper function."""

    def __init__(self, fn: ast.AST):
        self.fn = fn
        # var name -> {axis: normalized dim expr}; full unpacks fill all
        self.shapes: Dict[str, Dict[int, str]] = {}
        self.ranks: Dict[str, int] = {}
        self.dim_syms: set = set()           # names known to be dims
        self.facts: set = set()              # (dim_norm, block_norm)
        self.fact_blocks: set = set()        # block_norm with any fact
        self.assigns: Dict[str, ast.AST] = {}
        self.defaults: Dict[str, ast.AST] = {}
        self._collect()

    def _collect(self) -> None:
        a = self.fn.args
        pos = list(a.args) + list(a.kwonlyargs)
        defs = list(a.defaults) + list(a.kw_defaults)
        for arg, d in zip(reversed(pos), reversed(defs)):
            if d is not None:
                self.defaults[arg.arg] = d
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Assign):
                self._note_assign(node)
            elif isinstance(node, ast.Assert):
                self._note_assert(node.test)

    def _note_assign(self, node: ast.Assign) -> None:
        if len(node.targets) != 1:
            return
        tgt, val = node.targets[0], node.value
        if isinstance(tgt, ast.Name):
            self.assigns[tgt.id] = val
            shp = self.shape_of(val)
            if shp is not None:
                self.shapes[tgt.id] = shp
                self.ranks[tgt.id] = len(shp)
        elif isinstance(tgt, ast.Tuple) and all(
                isinstance(el, ast.Name) for el in tgt.elts):
            names = [el.id for el in tgt.elts]
            # B, S, H, D = x.shape  — full unpack defines dim symbols
            if isinstance(val, ast.Attribute) and val.attr == "shape":
                chain = attr_chain(val.value)
                if chain and len(chain) == 1:
                    var = chain[0]
                    self.shapes[var] = {i: n for i, n in enumerate(names)
                                        if n != "_"}
                    self.ranks[var] = len(names)
                self.dim_syms.update(n for n in names if n != "_")
                return
            if isinstance(val, ast.Tuple) and \
                    len(val.elts) == len(names):
                for name, el in zip(names, val.elts):
                    self.assigns[name] = el
                    # T, Hkv = k.shape[1], k.shape[2]
                    dim = self._shape_subscript(el)
                    if dim is not None:
                        var, axis = dim
                        self.shapes.setdefault(var, {})[axis] = name
                        self.dim_syms.add(name)

    @staticmethod
    def _shape_subscript(e: ast.AST) -> Optional[Tuple[str, int]]:
        if isinstance(e, ast.Subscript) \
                and isinstance(e.value, ast.Attribute) \
                and e.value.attr == "shape" \
                and isinstance(e.slice, ast.Constant) \
                and isinstance(e.slice.value, int):
            chain = attr_chain(e.value.value)
            if chain and len(chain) == 1:
                return chain[0], e.slice.value
        return None

    def _note_assert(self, test: ast.AST) -> None:
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
            for v in test.values:
                self._note_assert(v)
            return
        # X % b == 0
        if isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.ops[0], ast.Eq) \
                and isinstance(test.comparators[0], ast.Constant) \
                and test.comparators[0].value == 0 \
                and isinstance(test.left, ast.BinOp) \
                and isinstance(test.left.op, ast.Mod):
            dim, blk = _norm(test.left.left), _norm(test.left.right)
            self.facts.add((dim, blk))
            self.fact_blocks.add(blk)

    # --------------------------------------------------- symbolic shapes
    def shape_of(self, e: ast.AST) -> Optional[Dict[int, str]]:
        """Full symbolic shape of an expression, or None."""
        if isinstance(e, ast.Name):
            # partial dicts are fine: unknown axes fall back to the
            # dim-symbol / divisibility-fact path per axis
            return self.shapes.get(e.id)
        if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute):
            recv, meth = e.func.value, e.func.attr
            if meth == "reshape":
                args = e.args
                if len(args) == 1 and isinstance(args[0], ast.Tuple):
                    args = args[0].elts
                dims = {}
                for i, a in enumerate(args):
                    if isinstance(a, ast.Constant) and a.value == -1:
                        return None
                    dims[i] = _norm(a)
                return dims
            base = self.shape_of(recv)
            if base is None:
                return None
            if meth in _SHAPE_METHODS_PASS:
                return base
            if meth == "transpose":
                perm = [a.value for a in e.args
                        if isinstance(a, ast.Constant)]
                if len(perm) == len(base):
                    return {i: base[p] for i, p in enumerate(perm)}
                return None
            if meth == "swapaxes" and len(e.args) == 2 \
                    and all(isinstance(a, ast.Constant) for a in e.args):
                i, j = e.args[0].value, e.args[1].value
                out = dict(base)
                out[i], out[j] = base.get(j), base.get(i)
                return out
        return None

    # ------------------------------------------------ numeric evaluation
    def eval_num(self, e: ast.AST,
                 binds: Dict[str, int]) -> Optional[float]:
        if isinstance(e, ast.Constant) and isinstance(
                e.value, (int, float)):
            return e.value
        if isinstance(e, ast.Name):
            if e.id in binds:
                return binds[e.id]
            src = self.assigns.get(e.id)
            if src is not None:
                return self.eval_num(src, binds)
            d = self.defaults.get(e.id)
            if d is not None:
                return self.eval_num(d, binds)
            return None
        if isinstance(e, ast.BinOp):
            a = self.eval_num(e.left, binds)
            b = self.eval_num(e.right, binds)
            if a is None or b is None:
                return None
            if isinstance(e.op, ast.Add):
                return a + b
            if isinstance(e.op, ast.Sub):
                return a - b
            if isinstance(e.op, ast.Mult):
                return a * b
            if isinstance(e.op, ast.FloorDiv):
                return a // b if b else None
            if isinstance(e.op, ast.Div):
                return a / b if b else None
            if isinstance(e.op, ast.Mod):
                return a % b if b else None
            return None
        if isinstance(e, ast.Call):
            chain = attr_chain(e.func)
            if chain and chain[-1] in ("min", "max"):
                vals = [self.eval_num(a, binds) for a in e.args]
                if any(v is None for v in vals) or not vals:
                    return None
                return min(vals) if chain[-1] == "min" else max(vals)
            return None
        if isinstance(e, ast.UnaryOp) and isinstance(e.op, ast.USub):
            v = self.eval_num(e.operand, binds)
            return -v if v is not None else None
        return None


class KernelChecker:
    """All kernel-contract findings for one package tree."""

    def __init__(self, pkg: Package, tests_source: Optional[str] = None):
        self.pkg = pkg
        self.tests_source = tests_source
        self.findings: List[Finding] = []
        self.n_kernels = 0
        self.n_blockspecs = 0
        self.n_budgets = 0

    def flag(self, mod, line, qual, symbol, msg) -> None:
        self.findings.append(Finding(
            "kernel", mod.rel, line, qual, symbol, msg))

    # ----------------------------------------------------------- entry
    def check(self) -> List[Finding]:
        pkgs: Dict[str, List[str]] = {}
        for rel in self.pkg.modules:
            parts = pathlib.PurePosixPath(rel).parts
            if len(parts) == 3 and parts[0] == "kernels":
                pkgs.setdefault(parts[1], []).append(parts[2])
        for name, files in sorted(pkgs.items()):
            if "kernel.py" not in files:
                continue
            self.n_kernels += 1
            self._check_triple(name, files)
        for rel, mod in self.pkg.modules.items():
            if pathlib.PurePosixPath(rel).parts[:1] == ("kernels",):
                self._check_module(mod)
        return self.findings

    def _check_triple(self, name: str, files: List[str]) -> None:
        mod = self.pkg.modules[f"kernels/{name}/kernel.py"]
        for part in ("ops.py", "ref.py"):
            if part not in files:
                self.flag(mod, 1, "<package>", "triple",
                          f"kernel package {name!r} is missing {part} — "
                          "every kernel keeps its kernel/ops/ref triple")
        if self.tests_source is not None and \
                name not in self.tests_source:
            self.flag(mod, 1, "<package>", "parity-test",
                      f"kernel package {name!r} is not referenced by the "
                      "interpret-mode parity tests (tests/test_kernels.py)")

    # ------------------------------------------------------ per-module
    def _check_module(self, mod: ModuleInfo) -> None:
        funcs: List[ast.AST] = [
            n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for fn in funcs:
            calls = [n for n in ast.walk(fn) if self._is_pallas_apply(n)]
            if not calls:
                continue
            ctx = _Wrapper(fn)
            budget = annotation_span(mod, fn, "vmem-budget") \
                or annotation_span(
                    mod, fn.body[0] if fn.body else fn, "vmem-budget")
            footprint = 0.0
            unbound = False
            for call in calls:
                footprint_c, unbound_c = self._check_call(
                    mod, fn, ctx, call)
                footprint += footprint_c
                unbound = unbound or unbound_c
            self._check_budget(mod, fn, budget, footprint, unbound, ctx)

    @staticmethod
    def _is_pallas_apply(n: ast.AST) -> bool:
        """The ``pl.pallas_call(...)(operands...)`` outer application."""
        if not isinstance(n, ast.Call) or not isinstance(n.func, ast.Call):
            return False
        chain = attr_chain(n.func.func)
        return bool(chain) and chain[-1] == "pallas_call"

    # ---------------------------------------------------------- budget
    def _check_budget(self, mod, fn, budget, footprint_bytes,
                      unbound, ctx) -> None:
        qual = fn.name
        if budget is None:
            self.flag(mod, fn.lineno, qual, "vmem-budget",
                      "kernel wrapper has no '# vmem-budget: <MiB> MiB @ "
                      "sym=val ...' annotation — declare the VMEM "
                      "envelope this kernel is designed for")
            return
        try:
            mib, _ = parse_budget(budget)
        except _BudgetSyntax as ex:
            self.flag(mod, fn.lineno, qual, "vmem-syntax", str(ex))
            return
        self.n_budgets += 1
        if unbound:
            return                       # already flagged vmem-unbound
        got = footprint_bytes / (1024 * 1024)
        if got > mib:
            self.flag(mod, fn.lineno, qual, "vmem-budget",
                      f"static VMEM footprint {got:.2f} MiB exceeds the "
                      f"declared budget {mib:.2f} MiB at the annotated "
                      "bindings")

    # ------------------------------------------------------------ call
    def _check_call(self, mod, fn, ctx: _Wrapper,
                    call: ast.Call) -> Tuple[float, bool]:
        inner = call.func                  # the pallas_call(...) call
        kw = {k.arg: k.value for k in inner.keywords}
        n_prefetch = 0
        if "grid_spec" in kw and isinstance(kw["grid_spec"], ast.Call):
            for k in kw["grid_spec"].keywords:
                kw.setdefault(k.arg, k.value)
            npf = kw.get("num_scalar_prefetch")
            if isinstance(npf, ast.Constant):
                n_prefetch = int(npf.value)
        elif "grid_spec" in kw and isinstance(kw["grid_spec"], ast.Name):
            spec = ctx.assigns.get(kw["grid_spec"].id)
            if isinstance(spec, ast.Call):
                for k in spec.keywords:
                    kw.setdefault(k.arg, k.value)
                npf = kw.get("num_scalar_prefetch")
                if isinstance(npf, ast.Constant):
                    n_prefetch = int(npf.value)
        grid_rank = self._grid_rank(ctx, kw.get("grid"))
        in_specs = self._spec_list(ctx, kw.get("in_specs"))
        out_specs = self._spec_list(ctx, kw.get("out_specs"))
        operands = list(call.args)[n_prefetch:]
        out_shapes = self._out_shapes(ctx, kw.get("out_shape"))

        budget_binds: Dict[str, int] = {}
        note = annotation_span(mod, fn, "vmem-budget") \
            or annotation_span(mod, fn.body[0] if fn.body else fn,
                               "vmem-budget")
        if note is not None:
            try:
                _, budget_binds = parse_budget(note)
            except _BudgetSyntax:
                pass

        footprint = 0.0
        unbound = False
        pairs = list(zip(in_specs, operands + [None] * len(in_specs)))
        pairs += list(zip(out_specs, out_shapes + [None] * len(out_specs)))
        for spec, operand in pairs:
            if not isinstance(spec, ast.Call):
                continue
            self.n_blockspecs += 1
            block, lam = (spec.args + [None, None])[:2]
            if grid_rank is not None and isinstance(lam, ast.Lambda):
                arity = len(lam.args.args)
                want = grid_rank + n_prefetch
                if arity != want:
                    self.flag(mod, spec.lineno, fn.name, "grid-arity",
                              f"index lambda takes {arity} args but the "
                              f"grid has {grid_rank} dims"
                              + (f" + {n_prefetch} scalar-prefetch refs"
                                 if n_prefetch else ""))
            if isinstance(block, ast.Tuple):
                shape = self._operand_shape(ctx, operand)
                self._check_block(mod, fn, ctx, spec, block, shape)
                fp = self._block_bytes(ctx, block.elts, budget_binds,
                                       _OPERAND_BYTES)
                if fp is None:
                    if note is not None:
                        self.flag(mod, spec.lineno, fn.name,
                                  "vmem-unbound",
                                  "block shape has symbols the "
                                  "vmem-budget bindings don't pin — "
                                  "add sym=val to the annotation")
                    unbound = True
                else:
                    footprint += fp
        fp_s, un_s = self._scratch_bytes(mod, fn, ctx,
                                         kw.get("scratch_shapes"),
                                         budget_binds, note is not None)
        return footprint + fp_s, unbound or un_s

    def _scratch_bytes(self, mod, fn, ctx, scratch, binds,
                       have_note) -> Tuple[float, bool]:
        total, unbound = 0.0, False
        if not isinstance(scratch, (ast.List, ast.Tuple)):
            return total, unbound
        for el in scratch.elts:
            if not (isinstance(el, ast.Call) and el.args):
                continue
            shp = el.args[0]
            dtype = 4
            if len(el.args) > 1:
                chain = attr_chain(el.args[1])
                if chain:
                    dtype = _DTYPE_BYTES.get(chain[-1], 4)
            if isinstance(shp, ast.Tuple):
                fp = self._block_bytes(ctx, shp.elts, binds, dtype)
                if fp is None:
                    if have_note:
                        self.flag(mod, el.lineno, fn.name, "vmem-unbound",
                                  "scratch shape has symbols the "
                                  "vmem-budget bindings don't pin")
                    unbound = True
                else:
                    total += fp
        return total, unbound

    def _block_bytes(self, ctx, elts, binds, elem_bytes):
        prod = 1.0
        for el in elts:
            v = ctx.eval_num(el, binds)
            if v is None:
                return None
            prod *= v
        return prod * elem_bytes

    # ----------------------------------------------------- block shapes
    def _operand_shape(self, ctx, operand) -> Optional[Dict[int, str]]:
        if operand is None:
            return None
        if isinstance(operand, dict):
            return operand              # pre-resolved out_shape
        return ctx.shape_of(operand)

    def _check_block(self, mod, fn, ctx, spec, block, shape) -> None:
        if annotation_span(mod, spec, "masked") is not None:
            return
        for i, el in enumerate(block.elts):
            dim = shape.get(i) if shape is not None else None
            if self._block_ok(ctx, el, dim):
                continue
            bstr = _norm(el)
            if dim is None:
                self.flag(mod, spec.lineno, fn.name, "blockspec-divide",
                          f"block dim {bstr!r} (axis {i}) tiles an "
                          "operand of unknown shape with no "
                          "divisibility fact (assert dim % block == 0) "
                          "— or note '# masked: <reason>'")
            else:
                self.flag(mod, spec.lineno, fn.name, "blockspec-divide",
                          f"block dim {bstr!r} does not provably divide "
                          f"operand dim {dim!r} (axis {i}) — assert "
                          "divisibility or note '# masked: <reason>'")

    def _block_ok(self, ctx: _Wrapper, el: ast.AST,
                  dim: Optional[str]) -> bool:
        bstr = _norm(el)
        if isinstance(el, ast.Constant) and el.value == 1:
            return True
        if dim is not None:
            if bstr == dim:
                return True
            if (dim, bstr) in ctx.facts:
                return True
            if isinstance(el, ast.Constant):
                d = ctx.eval_num(ast.parse(dim, mode="eval").body, {})
                if d is not None and isinstance(el.value, int) \
                        and el.value and d % el.value == 0:
                    return True
            return False
        # unknown operand shape: accept blocks that are dim symbols /
        # products of known symbols, or that carry a divisibility fact
        if bstr in ctx.fact_blocks:
            return True
        names = [n.id for n in ast.walk(el) if isinstance(n, ast.Name)]
        return bool(names) and all(n in ctx.dim_syms for n in names)

    def _grid_rank(self, ctx, grid) -> Optional[int]:
        if isinstance(grid, ast.Name):
            grid = ctx.assigns.get(grid.id)
        if isinstance(grid, ast.Tuple):
            return len(grid.elts)
        return None

    def _spec_list(self, ctx, specs) -> List[ast.AST]:
        if specs is None:
            return []
        if isinstance(specs, ast.Name):
            specs = ctx.assigns.get(specs.id)
        if isinstance(specs, (ast.List, ast.Tuple)):
            return list(specs.elts)
        return [specs] if specs is not None else []

    def _out_shapes(self, ctx, out_shape) -> List[Optional[Dict[int, str]]]:
        """ShapeDtypeStruct exprs -> symbolic shapes, aligned to specs."""
        if out_shape is None:
            return []
        items = out_shape.elts if isinstance(
            out_shape, (ast.List, ast.Tuple)) else [out_shape]
        out = []
        for it in items:
            if isinstance(it, ast.Call) and it.args \
                    and isinstance(it.args[0], ast.Tuple):
                out.append({i: _norm(d)
                            for i, d in enumerate(it.args[0].elts)})
            else:
                out.append(None)
        return out


def check_kernels(pkg: Package,
                  tests_source: Optional[str] = None) -> List[Finding]:
    """Entry point: all kernel-contract findings for a package."""
    return KernelChecker(pkg, tests_source).check()


def count_kernels(pkg: Package) -> Tuple[int, int, int]:
    """(kernel packages, blockspecs, budgets) for the counts export."""
    c = KernelChecker(pkg, None)
    c.check()
    return c.n_kernels, c.n_blockspecs, c.n_budgets
