"""JIT-hazard checker (DESIGN.md §11).

Three hazards around ``jax.jit``:

1. **Undeclared argnums** — every jit site must say what it means:
   at least one of ``static_argnums/static_argnames/donate_argnums/
   donate_argnames/in_shardings/out_shardings``, or an inline
   ``# jit-ok: <reason>`` (or allowlist entry) acknowledging the bare
   wrap is intentional.

2. **Tracer branching** — Python ``if``/``while`` tests inside a
   jitted function may not reference traced parameters directly
   (``.shape``/``.ndim``/``.dtype`` reads and declared static args are
   fine); such branches bake one trace-time path silently.

3. **Unbucketed dynamic shapes** — the ``_PF_QUANTUM`` storm class:
   an int derived from ``len(...)`` that flows into an array
   constructor's shape tuple and then into a jitted entry point
   recompiles per distinct length.  The taint is cleared by the
   declared bucketing helpers (``_round_*`` calls or arithmetic
   against a ``*_QUANTUM`` constant).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.common import (Finding, FunctionInfo, Package,
                                   annotation, attr_chain)

_DECLARED_KWARGS = {"static_argnums", "static_argnames",
                    "donate_argnums", "donate_argnames",
                    "in_shardings", "out_shardings"}
_ARRAY_CTORS = {"zeros", "ones", "full", "empty", "zeros_like"}
_SANITIZER_SUFFIXES = ("_round_t", "_round_b")
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}


def _is_jit_func(expr: ast.AST) -> bool:
    chain = attr_chain(expr)
    return bool(chain) and chain[-1] == "jit" and (
        len(chain) == 1 or chain[0] in ("jax",))


def _jit_call_info(call: ast.Call) -> Optional[Dict]:
    """If ``call`` is ``jax.jit(...)`` or ``partial(jax.jit, ...)``,
    return its keyword set + static names."""
    if _is_jit_func(call.func):
        kws = call.keywords
    elif attr_chain(call.func) and attr_chain(call.func)[-1] == \
            "partial" and call.args and _is_jit_func(call.args[0]):
        kws = call.keywords
    else:
        return None
    declared = {k.arg for k in kws if k.arg in _DECLARED_KWARGS}
    static: Set[str] = set()
    for k in kws:
        if k.arg == "static_argnames":
            for sub in ast.walk(k.value):
                if isinstance(sub, ast.Constant) \
                        and isinstance(sub.value, str):
                    static.add(sub.value)
    return {"declared": declared, "static": static,
            "lineno": call.lineno}


def _iter_jit_sites(pkg: Package):
    """Yield (module, enclosing_qualname, call_info, decorated_def)."""
    for mod in pkg.modules.values():
        seen: Set[int] = set()

        # walk with enclosing-scope tracking
        def visit(node, qual):
            for child in ast.iter_child_nodes(node):
                q = qual
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef,
                                      ast.ClassDef)):
                    q = f"{qual}.{child.name}" if qual else child.name
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        for dec in child.decorator_list:
                            info = None
                            if isinstance(dec, ast.Call):
                                info = _jit_call_info(dec)
                            elif _is_jit_func(dec):
                                info = {"declared": set(),
                                        "static": set(),
                                        "lineno": dec.lineno}
                            if info is not None:
                                seen.add(id(dec))
                                yield (mod, q, info, child)
                if isinstance(child, ast.Assign) \
                        and isinstance(child.value, ast.Call) \
                        and id(child.value) not in seen:
                    info = _jit_call_info(child.value)
                    if info is not None:
                        seen.add(id(child.value))
                        fn = None
                        if child.value.args:
                            tgt = child.value.args[0]
                            if isinstance(tgt, ast.Name) \
                                    and tgt.id in mod.functions:
                                fn = mod.functions[tgt.id].node
                        # `_step = jax.jit(fn, ...)`: calls through the
                        # assigned name are jit entries too
                        info["aliases"] = [
                            t.id for t in child.targets
                            if isinstance(t, ast.Name)]
                        yield (mod, qual or "<module>", info, fn)
                if isinstance(child, ast.Call) and id(child) not in seen:
                    info = _jit_call_info(child)
                    if info is not None:
                        fn = None
                        # jax.jit(local_fn, ...) — resolve for branch
                        # checks on the wrapped function
                        if child.args:
                            tgt = child.args[0]
                            if isinstance(tgt, ast.Name) \
                                    and tgt.id in mod.functions:
                                fn = mod.functions[tgt.id].node
                        yield (mod, qual or "<module>", info, fn)
                        continue  # don't re-yield partial's inner jit
                yield from visit(child, q)
        yield from visit(mod.tree, "")


def _check_tracer_branches(mod, qual: str, node, static: Set[str],
                           findings: List[Finding]) -> None:
    if node is None or not isinstance(node, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
        return
    args = node.args
    params = [a.arg for a in list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)]
    dynamic = {p for p in params if p not in static and p != "self"}

    def tracer_refs(expr) -> List[str]:
        hits: List[str] = []

        def rec(e):
            if isinstance(e, ast.Attribute):
                if e.attr in _STATIC_ATTRS:
                    return  # x.shape[...] is trace-time static
                rec(e.value)
            elif isinstance(e, ast.Call):
                fc = attr_chain(e.func)
                if fc and fc[-1] == "len":
                    return  # len(x) of a traced array is static
                for a in e.args:
                    rec(a)
                for k in e.keywords:
                    rec(k.value)
            elif isinstance(e, ast.Name):
                if e.id in dynamic:
                    hits.append(e.id)
            else:
                for c in ast.iter_child_nodes(e):
                    rec(c)
        rec(expr)
        return hits

    for sub in ast.walk(node):
        test = None
        if isinstance(sub, (ast.If, ast.While)):
            test = sub.test
        elif isinstance(sub, ast.IfExp):
            test = sub.test
        if test is None:
            continue
        refs = tracer_refs(test)
        if refs:
            findings.append(Finding(
                "jit", mod.rel, sub.lineno, qual, refs[0],
                f"Python branch on traced value(s) "
                f"{', '.join(sorted(set(refs)))} inside jitted "
                f"{node.name} — the condition is baked at trace time"))


class _TaintWalk:
    """Per-function forward taint: len()-derived ints reaching array
    ctor shapes that flow into jitted entry points."""

    def __init__(self, pkg: Package, fi: FunctionInfo,
                 entries: Dict[str, Set[str]],
                 jit_funcs: Dict[Tuple[str, str], Set[str]],
                 findings: List[Finding]) -> None:
        self.pkg = pkg
        self.fi = fi
        self.ci = pkg.classes.get(fi.cls) if fi.cls else None
        self.entries = entries          # ClassName -> jit attr names
        self.jit_funcs = jit_funcs      # (module, fn) -> static names
        self.findings = findings
        self.tainted: Set[str] = set()
        self.ctor_tainted: Set[str] = set()
        self.mod = pkg.modules[fi.module]

    # -- expression taint -----------------------------------------
    def _is_quantum_ref(self, e: ast.AST) -> bool:
        chain = attr_chain(e)
        return bool(chain) and chain[-1].upper().endswith("_QUANTUM")

    def _is_sanitizer(self, call: ast.Call) -> bool:
        chain = attr_chain(call.func)
        if not chain:
            return False
        tail = chain[-1]
        return tail.endswith(_SANITIZER_SUFFIXES) \
            or tail.startswith(("round_to", "_round"))

    def expr_taint(self, e: ast.AST) -> bool:
        if e is None:
            return False
        if isinstance(e, ast.Call):
            if self._is_sanitizer(e):
                return False
            chain = attr_chain(e.func)
            if chain and chain[-1] == "len":
                return True
            return False
        if isinstance(e, ast.BinOp):
            if self._is_quantum_ref(e.left) \
                    or self._is_quantum_ref(e.right):
                return False
            return self.expr_taint(e.left) or self.expr_taint(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.expr_taint(e.operand)
        if isinstance(e, ast.IfExp):
            return self.expr_taint(e.body) or self.expr_taint(e.orelse)
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        return False

    def _ctor_shape_taint(self, e: ast.AST) -> Optional[int]:
        """Line no of a tainted-shape array ctor inside ``e``."""
        for sub in ast.walk(e):
            if isinstance(sub, ast.Name) and sub.id in self.ctor_tainted:
                return sub.lineno
            if not isinstance(sub, ast.Call):
                continue
            chain = attr_chain(sub.func)
            if not chain or chain[-1] not in _ARRAY_CTORS:
                continue
            if not sub.args or not isinstance(sub.args[0], ast.Tuple):
                continue
            for elt in sub.args[0].elts:
                if self.expr_taint(elt):
                    return sub.lineno
        return None

    # -- linear statement pass ------------------------------------
    def run(self) -> None:
        for stmt in ast.walk(self.fi.node):
            if isinstance(stmt, ast.Assign):
                t = self.expr_taint(stmt.value)
                ct = self._ctor_shape_taint(stmt.value) is not None
                for tgt in stmt.targets:
                    for name in self._target_names(tgt):
                        if t:
                            self.tainted.add(name)
                        if ct:
                            self.ctor_tainted.add(name)
            elif isinstance(stmt, ast.AugAssign) \
                    and isinstance(stmt.target, ast.Name):
                if self.expr_taint(stmt.value):
                    self.tainted.add(stmt.target.id)
        for stmt in ast.walk(self.fi.node):
            if isinstance(stmt, ast.Call):
                self._check_entry_call(stmt)

    @staticmethod
    def _target_names(tgt: ast.AST) -> List[str]:
        if isinstance(tgt, ast.Name):
            return [tgt.id]
        if isinstance(tgt, (ast.Tuple, ast.List)):
            return [n.id for n in tgt.elts if isinstance(n, ast.Name)]
        return []

    def _entry_of(self, call: ast.Call) -> Optional[Tuple[str, Set[str]]]:
        chain = attr_chain(call.func)
        if not chain:
            return None
        if chain[0] == "self" and len(chain) == 2 and self.ci \
                and chain[1] in self.ci.jit_attrs:
            return (f"{self.ci.name}.{chain[1]}", set())
        if len(chain) == 1:
            key = (self.fi.module, chain[0])
            if key in self.jit_funcs:
                return (chain[0], self.jit_funcs[key])
            imp = self.mod.from_imports.get(chain[0])
            if imp:
                for (m, fn), static in self.jit_funcs.items():
                    if fn == imp[1]:
                        return (chain[0], static)
        return None

    def _check_entry_call(self, call: ast.Call) -> None:
        entry = self._entry_of(call)
        if entry is None:
            return
        name, static = entry
        for arg in call.args:
            ln = self._ctor_shape_taint(arg)
            if ln is not None:
                self.findings.append(Finding(
                    "jit", self.fi.module, call.lineno,
                    self.fi.qualname, name,
                    f"arg to jitted entry {name} carries a len()-"
                    f"derived array shape (ctor at line {ln}) that "
                    f"never passed a bucketing helper — recompile "
                    f"storm (_PF_QUANTUM class)"))
        for kw in call.keywords:
            if kw.arg in static and self.expr_taint(kw.value):
                self.findings.append(Finding(
                    "jit", self.fi.module, call.lineno,
                    self.fi.qualname, name,
                    f"static arg {kw.arg}= of jitted entry {name} is "
                    f"len()-derived and unbucketed — every distinct "
                    f"value recompiles"))


def check_jit(pkg: Package) -> List[Finding]:
    """Entry point: all JIT-hazard findings for a package."""
    findings: List[Finding] = []
    jit_funcs: Dict[Tuple[str, str], Set[str]] = {}
    n_sites = 0
    for mod, qual, info, fn in _iter_jit_sites(pkg):
        n_sites += 1
        note = annotation(mod, info["lineno"], "jit-ok")
        ok_comment = note is not None and note.strip()
        if not info["declared"] and not ok_comment:
            findings.append(Finding(
                "jit", mod.rel, info["lineno"], qual, "jax.jit",
                "jit site declares no static/donate argnums or "
                "shardings — say what you mean, or annotate "
                "'# jit-ok: <reason>'"))
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            _check_tracer_branches(mod, qual, fn, info["static"],
                                   findings)
            jit_funcs[(mod.rel, fn.name)] = set(info["static"])
        for alias in info.get("aliases", ()):
            jit_funcs[(mod.rel, alias)] = set(info["static"])
    for fi in pkg.all_functions():
        _TaintWalk(pkg, fi, {c.name: c.jit_attrs
                             for c in pkg.classes.values()},
                   jit_funcs, findings).run()
    return findings


def count_jit_sites(pkg: Package) -> int:
    """Number of jax.jit call sites in the package (for the nightly
    BENCH export)."""
    return sum(1 for _ in _iter_jit_sites(pkg))
