"""Host-sync auditor (DESIGN.md §11).

Finds device→host synchronization points inside the engine's decode
hot path: ``.item()``, ``int()/float()`` on values of unknown (possibly
device) origin, ``np.asarray``/``np.array``, ``jax.device_get`` and
``block_until_ready`` — in any function reachable from the
``InferenceEngine`` step loop through the intra-package call graph
(self-methods, typed attributes, module functions, from-imports).

Every hit must either be intentional (an ``allowlist.toml`` entry with
``kind = "sync"`` or ``kind = "host-data"`` and a reason) or go away;
the allowlist is how the per-step sync budget only ever goes DOWN.
``# not-a-sync: <reason>`` suppresses inline for the host-data cases
that are obvious at the call site.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.common import (Finding, FunctionInfo, Package,
                                   annotation, attr_chain)

DEFAULT_ROOTS = ("InferenceEngine._run_loop", "InferenceEngine._step")

# calls whose results are host-side ints/floats/arrays — int()/float()
# on these is data shuffling, not a device sync
_HOST_PRODUCERS = {"len", "sorted", "range", "min", "max", "sum",
                   "enumerate", "list", "tuple", "dict", "set",
                   "monotonic", "perf_counter", "time"}


def build_call_graph(pkg: Package) -> Dict[str, Set[str]]:
    """qualname -> callee qualnames, via the shared resolvers."""
    graph: Dict[str, Set[str]] = {}
    for fi in pkg.all_functions():
        mod = pkg.modules[fi.module]
        local_types = pkg.local_types_for(fi)
        out = graph.setdefault(fi.qualname, set())
        for sub in ast.walk(fi.node):
            if not isinstance(sub, ast.Call):
                continue
            callee = pkg.resolve_callee(mod, fi, sub, local_types)
            if callee is not None:
                out.add(callee.qualname)
    return graph


def reachable_from(graph: Dict[str, Set[str]],
                   roots: Tuple[str, ...]) -> Set[str]:
    """Transitive closure of the call graph from the hot-path roots."""
    seen: Set[str] = set()
    frontier = [r for r in roots if r in graph or True]
    while frontier:
        node = frontier.pop()
        if node in seen:
            continue
        seen.add(node)
        frontier.extend(graph.get(node, ()))
    return seen


class _SyncScan:
    """Scan one hot-path function for sync patterns."""

    def __init__(self, pkg: Package, fi: FunctionInfo,
                 findings: List[Finding]) -> None:
        self.pkg = pkg
        self.fi = fi
        self.mod = pkg.modules[fi.module]
        self.findings = findings
        self.host_locals: Set[str] = set()
        self.np_aliases = {a for a, full in
                           self.mod.import_alias.items()
                           if full == "numpy"}
        self.jax_aliases = {a for a, full in
                            self.mod.import_alias.items()
                            if full == "jax"}

    def _flag(self, node: ast.AST, symbol: str, what: str) -> None:
        note = annotation(self.mod, node.lineno, "not-a-sync")
        if note is not None and note.strip():
            return
        self.findings.append(Finding(
            "hostsync", self.fi.module, node.lineno, self.fi.qualname,
            symbol,
            f"{what} in hot-path function {self.fi.qualname} "
            f"(reachable from the engine step loop)"))

    def _value_is_host(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Constant):
            return True
        if isinstance(e, ast.Name):
            return e.id in self.host_locals
        if isinstance(e, ast.Subscript):
            return self._value_is_host(e.value)
        if isinstance(e, ast.Attribute):
            # attribute reads (config ints, lengths) are host state;
            # only locals assigned from device computations are suspect
            return True
        if isinstance(e, ast.BinOp):
            return self._value_is_host(e.left) \
                and self._value_is_host(e.right)
        if isinstance(e, ast.Call):
            chain = attr_chain(e.func)
            if chain and (chain[-1] in _HOST_PRODUCERS
                          or chain[0] in self.np_aliases):
                return True
            return False
        return False

    def _note_host_local(self, stmt: ast.Assign) -> None:
        v = stmt.value
        is_host = False
        if isinstance(v, (ast.List, ast.Tuple, ast.Dict, ast.Set,
                          ast.ListComp, ast.DictComp, ast.Constant)):
            is_host = True
        elif isinstance(v, ast.Call):
            chain = attr_chain(v.func)
            if chain and (chain[0] in self.np_aliases
                          or chain[-1] in _HOST_PRODUCERS):
                is_host = True
        if is_host:
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    self.host_locals.add(tgt.id)

    def run(self) -> None:
        # pass 1: which locals are host-side data
        for stmt in ast.walk(self.fi.node):
            if isinstance(stmt, ast.Assign):
                self._note_host_local(stmt)
        # pass 2: sync patterns
        for sub in ast.walk(self.fi.node):
            if not isinstance(sub, ast.Call):
                continue
            chain = attr_chain(sub.func)
            if chain is None:
                continue
            tail = chain[-1]
            if tail == "item" and len(chain) > 1:
                self._flag(sub, ".item", "device scalar .item() sync")
            elif tail == "block_until_ready":
                self._flag(sub, "block_until_ready",
                           "explicit device barrier")
            elif tail == "device_get" and (
                    len(chain) == 1 or chain[0] in self.jax_aliases):
                self._flag(sub, "device_get", "jax.device_get D2H copy")
            elif tail in ("asarray", "array") and len(chain) > 1 \
                    and chain[0] in self.np_aliases:
                self._flag(sub, f"np.{tail}",
                           f"np.{tail} D2H materialization")
            elif tail in ("int", "float") and len(chain) == 1 \
                    and sub.args:
                if not self._value_is_host(sub.args[0]):
                    self._flag(sub, tail,
                               f"{tail}() on a value of device origin")


def check_hostsync(pkg: Package,
                   roots: Tuple[str, ...] = DEFAULT_ROOTS) -> \
        List[Finding]:
    """Entry point: all host-sync findings in hot-path functions."""
    findings: List[Finding] = []
    graph = build_call_graph(pkg)
    hot = reachable_from(graph, roots)
    by_qual = {fi.qualname: fi for fi in pkg.all_functions()}
    for qual in sorted(hot):
        fi = by_qual.get(qual)
        if fi is None:
            continue
        _SyncScan(pkg, fi, findings).run()
    return findings


def hot_path_size(pkg: Package,
                  roots: Tuple[str, ...] = DEFAULT_ROOTS) -> int:
    """Number of functions reachable from the step loop (BENCH
    export)."""
    graph = build_call_graph(pkg)
    by_qual = {fi.qualname for fi in pkg.all_functions()}
    return len(reachable_from(graph, roots) & by_qual)
