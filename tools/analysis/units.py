"""Dimensional analysis over the cost model (DESIGN.md §11.6).

``# unit:`` annotations declare the physical unit of dataclass fields,
attributes, and function returns/params; this checker propagates them
through the arithmetic and flags provable inconsistencies — the class
of bug where a bytes/s figure quietly prices a bytes/token term, or a
per-step time is multiplied by a token count twice.

Annotation grammar::

    flops: float      # unit: flops/s
    hbm_bw: float     # unit: bytes/s @hbm
    # unit: eff_p=tokens n=1 -> s s          (def line: params -> returns)
    def _roofline_times(self, v, eff_p, n): ...
    t_step = ...      # unit: s/token (explicit cast, note in parens)

A unit is a quotient of base dimensions (``s``, ``bytes``, ``tokens``,
``flops``), ``1`` for dimensionless, or ``-`` for "don't check".  An
optional ``@channel`` tag marks WHICH physical path a byte quantity or
bandwidth belongs to: quantities (``@weights``, ``@kv``) may only be
divided by bandwidths of a compatible path (``@host``, ``@hbm``,
``@link``) — pricing a KV migration against ``host_bw`` is a finding
even though the dimensions (bytes ÷ bytes/s) agree.

The checker is deliberately conservative: unknown units are wildcards,
numeric literals are dimensionless-tolerant, and only provable
mismatches are flagged.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from tools.analysis.common import (Finding, Package, annotation,
                                   annotation_span, attr_chain)

BASES = {"s", "bytes", "tokens", "flops"}
_SINGULAR = {"byte": "bytes", "token": "tokens", "flop": "flops",
             "sec": "s", "second": "s", "seconds": "s"}
QUANTITY_TAGS = {"weights", "kv"}
PATH_TAGS = {"host", "hbm", "link"}
# which physical path may price which byte quantity
COMPAT = {"weights": {"host", "hbm"}, "kv": {"hbm", "link"}}


@dataclasses.dataclass(frozen=True)
class Unit:
    """Dimension vector (sorted (base, exponent) pairs) + channel tags."""

    dims: Tuple[Tuple[str, int], ...]
    channels: frozenset = frozenset()

    @property
    def dimless(self) -> bool:
        return not self.dims

    def render(self) -> str:
        if not self.dims:
            return "1"
        num = [b if e == 1 else f"{b}^{e}" for b, e in self.dims if e > 0]
        den = [b if e == -1 else f"{b}^{-e}" for b, e in self.dims if e < 0]
        out = "*".join(num) or "1"
        if den:
            out += "/" + "/".join(den)
        if self.channels:
            out += " @" + ",@".join(sorted(self.channels))
        return out


DIMLESS = Unit(dims=())


def _mk(dims: Dict[str, int], channels=frozenset()) -> Unit:
    return Unit(tuple(sorted((b, e) for b, e in dims.items() if e)),
                frozenset(channels))


class UnitSyntaxError(ValueError):
    pass


def parse_unit(text: str) -> Optional[Unit]:
    """``bytes/s @hbm`` -> Unit; ``-`` -> None (don't check)."""
    text = re.sub(r"\(.*\)\s*$", "", text).strip()   # drop trailing note
    if not text or text == "-":
        return None
    channels = set()
    frag = []
    for tok in text.split():
        if tok.startswith("@"):
            channels.add(tok[1:])
        else:
            frag.append(tok)
    spec = "".join(frag)
    if "@" in spec:                      # inline tag: bytes/s@hbm
        spec, _, tag = spec.partition("@")
        channels.add(tag)
    bad = channels - QUANTITY_TAGS - PATH_TAGS
    if bad:
        raise UnitSyntaxError(f"unknown channel tag @{sorted(bad)[0]}")
    dims: Dict[str, int] = {}
    for i, part in enumerate(spec.split("/")):
        part = _SINGULAR.get(part, part)
        if part == "1" or part == "":
            if i == 0:
                continue
            raise UnitSyntaxError(f"bad unit {text!r}")
        if part not in BASES:
            raise UnitSyntaxError(f"unknown base unit {part!r} in {text!r}")
        dims[part] = dims.get(part, 0) + (1 if i == 0 else -1)
    return _mk(dims, channels)


@dataclasses.dataclass
class FnUnits:
    """Declared units of one annotated function."""

    qualname: str
    params: Dict[str, Optional[Unit]]
    returns: List[Optional[Unit]]       # len > 1 => tuple return
    pos: Dict[str, int] = dataclasses.field(default_factory=dict)


def parse_def_annotation(text: str):
    """``a=tokens n=1 -> s s`` -> (params dict, returns list)."""
    text = re.sub(r"\(.*\)\s*$", "", text).strip()
    if "->" in text:
        lhs, _, rhs = text.partition("->")
    else:
        lhs, rhs = "", text
    params: Dict[str, Optional[Unit]] = {}
    for tok in lhs.split():
        if "=" not in tok:
            raise UnitSyntaxError(f"param spec {tok!r} needs name=unit")
        name, _, u = tok.partition("=")
        params[name] = parse_unit(u)
    returns = [parse_unit(tok) for tok in rhs.split()] or [None]
    return params, returns


def _same_dims(a: Unit, b: Unit) -> bool:
    return a.dims == b.dims


class _UnitWalk:
    """Infer units through one function body, in statement order."""

    def __init__(self, checker: "UnitChecker", mod, fi, decl: FnUnits):
        self.c = checker
        self.mod = mod
        self.fi = fi
        self.decl = decl
        self.env: Dict[str, Optional[Unit]] = dict(decl.params)

    def flag(self, node, symbol, msg):
        self.c.findings.append(Finding(
            "units", self.mod.rel, node.lineno, self.fi.qualname,
            symbol, msg))

    # -------------------------------------------------------------- eval
    def eval(self, e: ast.AST) -> Optional[Unit]:
        if isinstance(e, ast.Constant):
            return DIMLESS if isinstance(e.value, (int, float)) else None
        if isinstance(e, ast.Name):
            return self.env.get(e.id)
        if isinstance(e, ast.Attribute):
            chain = attr_chain(e)
            if chain:
                return self.c.field_units.get(chain[-1])
            return None
        if isinstance(e, ast.UnaryOp):
            return self.eval(e.operand)
        if isinstance(e, ast.BinOp):
            return self._binop(e)
        if isinstance(e, ast.Compare):
            self._addlike([e.left] + list(e.comparators), e, "compare")
            return DIMLESS
        if isinstance(e, ast.BoolOp):
            return DIMLESS
        if isinstance(e, ast.IfExp):
            return self._addlike([e.body, e.orelse], e, "branches")
        if isinstance(e, ast.Call):
            return self._call(e)
        if isinstance(e, ast.Subscript):
            base = e.value
            if isinstance(base, ast.Call):
                units = self._call_tuple(base)
                ix = e.slice
                if units is not None and isinstance(ix, ast.Constant) \
                        and isinstance(ix.value, int) \
                        and 0 <= ix.value < len(units):
                    return units[ix.value]
            return None
        if isinstance(e, (ast.Tuple, ast.List)):
            return None                  # handled by _eval_returns
        return None

    def _binop(self, e: ast.BinOp) -> Optional[Unit]:
        a, b = self.eval(e.left), self.eval(e.right)
        if isinstance(e.op, (ast.Add, ast.Sub)):
            return self._addlike2(a, b, e)
        if isinstance(e.op, ast.Mult):
            if a is None or b is None:
                return None
            return _mk({k: v for k, v in self._dimsum(a, b, +1).items()},
                       a.channels | b.channels)
        if isinstance(e.op, (ast.Div, ast.FloorDiv)):
            if a is None or b is None:
                return None
            self._check_channels(a, b, e)
            ch = frozenset() if (b.channels & PATH_TAGS) \
                else a.channels | b.channels
            return _mk(self._dimsum(a, b, -1), ch)
        if isinstance(e.op, ast.Mod):
            return a
        if isinstance(e.op, ast.Pow):
            return None
        return None

    @staticmethod
    def _dimsum(a: Unit, b: Unit, sign: int) -> Dict[str, int]:
        out = dict(a.dims)
        for base, exp in b.dims:
            out[base] = out.get(base, 0) + sign * exp
        return out

    def _check_channels(self, num: Unit, den: Unit, node) -> None:
        paths = den.channels & PATH_TAGS
        if not paths:
            return
        for q in num.channels & QUANTITY_TAGS:
            for p in paths:
                if p not in COMPAT.get(q, set()):
                    self.flag(node, "channel",
                              f"@{q} bytes priced over the @{p} path "
                              f"(allowed: {sorted(COMPAT.get(q, set()))})"
                              " — wrong bandwidth for this quantity")

    def _addlike2(self, a, b, node, what="terms") -> Optional[Unit]:
        known = [u for u in (a, b) if u is not None and not u.dimless]
        if len(known) == 2 and not _same_dims(known[0], known[1]):
            self.flag(node, "mix",
                      f"incompatible {what}: {known[0].render()} vs "
                      f"{known[1].render()}")
            return None
        if not known:
            return DIMLESS if a is not None and b is not None else None
        u = known[0]
        ch = (a.channels if a else frozenset()) | \
            (b.channels if b else frozenset())
        return Unit(u.dims, frozenset(ch))

    def _addlike(self, exprs, node, what) -> Optional[Unit]:
        out: Optional[Unit] = DIMLESS
        for e in exprs:
            out = self._addlike2(out, self.eval(e), node, what)
        return out

    # ------------------------------------------------------------- calls
    def _call(self, e: ast.Call) -> Optional[Unit]:
        units = self._call_tuple(e)
        if units is None:
            return None
        return units[0] if len(units) == 1 else None

    def _call_tuple(self, e: ast.Call) -> Optional[List[Optional[Unit]]]:
        chain = attr_chain(e.func)
        name = chain[-1] if chain else None
        if name in ("min", "max", "sum", "abs"):
            args = []
            for a in e.args:
                if isinstance(a, (ast.GeneratorExp, ast.ListComp)):
                    args.append(a.elt)
                else:
                    args.append(a)
            return [self._addlike(args, e, f"{name}() arguments")]
        if name in ("float", "int", "round", "ceil", "floor"):
            return [self.eval(e.args[0])] if e.args else None
        if name == "len":
            return [DIMLESS]
        decl = self.c.functions.get(name) if name else None
        if decl is not None:
            self._check_args(e, decl)
            return decl.returns
        return None

    def _check_args(self, e: ast.Call, decl: FnUnits) -> None:
        for pname, want in decl.params.items():
            if want is None:
                continue
            got_expr = None
            if pname in decl.pos and len(e.args) > decl.pos[pname]:
                got_expr = e.args[decl.pos[pname]]
            for kw in e.keywords:
                if kw.arg == pname:
                    got_expr = kw.value
            if got_expr is None:
                continue
            got = self.eval(got_expr)
            if got is not None and not got.dimless \
                    and not _same_dims(got, want):
                self.flag(e, "arg",
                          f"argument {pname}={got.render()} but "
                          f"{decl.qualname} declares {want.render()}")

    # --------------------------------------------------------- statements
    def walk(self, stmts) -> None:
        for s in stmts:
            self.stmt(s)

    def stmt(self, s: ast.AST) -> None:
        if isinstance(s, ast.Assign):
            self._assign(s, s.targets, s.value)
        elif isinstance(s, ast.AnnAssign) and s.value is not None:
            self._assign(s, [s.target], s.value)
        elif isinstance(s, ast.AugAssign):
            if isinstance(s.target, ast.Name):
                cur = self.env.get(s.target.id)
                if isinstance(s.op, (ast.Add, ast.Sub)):
                    self.env[s.target.id] = self._addlike2(
                        cur, self.eval(s.value), s)
                else:
                    self.env[s.target.id] = None
        elif isinstance(s, ast.Return) and s.value is not None:
            self._return(s)
        elif isinstance(s, ast.Expr):
            self.eval(s.value)
        elif isinstance(s, (ast.If, ast.For, ast.While)):
            if isinstance(s, ast.For) and isinstance(s.target, ast.Name):
                self.env[s.target.id] = None
            self.walk(s.body)
            self.walk(s.orelse)
        elif isinstance(s, ast.With):
            self.walk(s.body)
        elif isinstance(s, ast.Try):
            self.walk(s.body)
            for h in s.handlers:
                self.walk(h.body)
            self.walk(s.orelse)
            self.walk(s.finalbody)

    def _assign(self, s, targets, value) -> None:
        cast = annotation_span(self.mod, s, "unit")
        inferred = None
        if isinstance(value, ast.Call):
            units = self._call_tuple(value)
            inferred = units[0] if units and len(units) == 1 else None
            tup = units
        else:
            inferred = self.eval(value)
            tup = None
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                if cast is not None:
                    try:
                        self.env[tgt.id] = parse_unit(cast)
                    except UnitSyntaxError as ex:
                        self.flag(s, "unit-syntax", str(ex))
                else:
                    self.env[tgt.id] = inferred
            elif isinstance(tgt, ast.Tuple) and all(
                    isinstance(el, ast.Name) for el in tgt.elts):
                parts: List[Optional[Unit]] = [None] * len(tgt.elts)
                if tup is not None and len(tup) == len(tgt.elts):
                    parts = list(tup)
                elif isinstance(value, ast.Tuple) \
                        and len(value.elts) == len(tgt.elts):
                    parts = [self.eval(el) for el in value.elts]
                for el, u in zip(tgt.elts, parts):
                    self.env[el.id] = u

    def _return(self, s: ast.Return) -> None:
        decl = self.decl.returns
        vals: List[Optional[Unit]]
        if isinstance(s.value, ast.Tuple):
            vals = [self.eval(el) for el in s.value.elts]
        elif isinstance(s.value, ast.Call):
            vals = self._call_tuple(s.value) or [None]
        else:
            vals = [self.eval(s.value)]
        if len(decl) > 1 and len(vals) != len(decl):
            return                      # arity checked elsewhere (typing)
        for i, (want, got) in enumerate(zip(decl, vals)):
            if want is None or got is None or got.dimless:
                continue
            if not _same_dims(got, want):
                where = f" (element {i})" if len(decl) > 1 else ""
                self.flag(s, "return",
                          f"returns {got.render()} but declares "
                          f"{want.render()}{where}")


class UnitChecker:
    """Collect ``# unit:`` annotations, then walk annotated functions."""

    def __init__(self, pkg: Package):
        self.pkg = pkg
        self.findings: List[Finding] = []
        self.field_units: Dict[str, Optional[Unit]] = {}
        self.functions: Dict[str, FnUnits] = {}
        self.n_fields = 0
        self._collect()

    # ------------------------------------------------------- collection
    def _collect(self) -> None:
        for mod in self.pkg.modules.values():
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef):
                    for stmt in node.body:
                        self._field(mod, node.name, stmt, class_level=True)
                elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                    self._field(mod, None, node, class_level=False)
                elif isinstance(node, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    self._function(mod, node)

    def _field(self, mod, cls, stmt, class_level) -> None:
        if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            return
        val = annotation(mod, stmt.lineno, "unit")
        if val is None:
            return
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for tgt in targets:
            attr = None
            if class_level and isinstance(tgt, ast.Name):
                attr = tgt.id
            else:
                chain = attr_chain(tgt)
                if chain and len(chain) == 2 and chain[0] == "self":
                    attr = chain[1]
            if attr is None:
                continue                 # local cast, handled in _UnitWalk
            try:
                unit = parse_unit(val)
            except UnitSyntaxError as ex:
                self.findings.append(Finding(
                    "units", mod.rel, stmt.lineno, cls or "<module>",
                    "unit-syntax", str(ex)))
                continue
            prev = self.field_units.get(attr)
            if prev is not None and unit is not None \
                    and not _same_dims(prev, unit):
                self.findings.append(Finding(
                    "units", mod.rel, stmt.lineno, cls or "<module>",
                    "unit-conflict",
                    f"field {attr!r} annotated {unit.render()} here but "
                    f"{prev.render()} elsewhere"))
                continue
            self.field_units[attr] = unit
            self.n_fields += 1

    def _function(self, mod, node) -> None:
        val = annotation(mod, node.lineno, "unit")
        if val is None:
            return
        try:
            params, returns = parse_def_annotation(val)
        except UnitSyntaxError as ex:
            self.findings.append(Finding(
                "units", mod.rel, node.lineno, node.name,
                "unit-syntax", str(ex)))
            return
        argnames = [a.arg for a in node.args.args if a.arg != "self"]
        decl = FnUnits(qualname=node.name, params=params, returns=returns,
                       pos={n: i for i, n in enumerate(argnames)})
        for p in params:
            if p not in argnames:
                self.findings.append(Finding(
                    "units", mod.rel, node.lineno, node.name,
                    "unit-syntax",
                    f"unit annotation names unknown param {p!r}"))
        self.functions[node.name] = decl

    # ------------------------------------------------------------- check
    def check(self) -> List[Finding]:
        for mod in self.pkg.modules.values():
            for fi in mod.functions.values():
                self._check_fn(mod, fi)
            for cname in mod.classes:
                for fi in self.pkg.classes[cname].methods.values():
                    self._check_fn(mod, fi)
        return self.findings

    def _check_fn(self, mod, fi) -> None:
        decl = self.functions.get(fi.name)
        if decl is None or annotation(mod, fi.node.lineno, "unit") is None:
            return
        _UnitWalk(self, mod, fi, decl).walk(fi.node.body)


def check_units(pkg: Package) -> List[Finding]:
    """Entry point: all dimensional-analysis findings for a package."""
    return UnitChecker(pkg).check()


def count_units(pkg: Package) -> Tuple[int, int]:
    """(annotated fields, annotated functions) for the counts export."""
    c = UnitChecker(pkg)
    return c.n_fields, len(c.functions)
