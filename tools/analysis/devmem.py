"""Device/host memory-space discipline (DESIGN.md §11.4).

Modules opt in by carrying at least one ``# memspace:`` annotation
(``device`` / ``host`` on attribute assignments, ``staging`` on the
functions that are *allowed* to cross the boundary).  Within an opted-in
module the checker taint-tracks array provenance and flags:

* **d2h** — ``np.asarray`` / ``np.array`` / ``jax.device_get`` applied
  to a device-tainted value outside a ``# memspace: staging`` function.
  Each implicit download is a blocking sync in the hot path; deliberate
  ones carry ``# not-a-transfer: <reason>`` inline or an allowlist
  entry with ``kind = "transfer"`` (those are the *budgeted* syncs the
  engine already accounts in ``stats.d2h_bytes``).
* **h2d-loop** — ``jnp.asarray`` / ``jnp.array`` of a host-tainted
  value lexically inside a loop: a per-iteration upload that belongs
  hoisted above the loop (or batched).
* **use-after-donate** — reading an array that was passed in a donated
  position of a ``donate_argnums`` jit.  After donation the buffer is
  invalid; the read is only legal once the name is rebound (directly,
  or by a callee method known to rebind the attr, e.g.
  ``kv.adopt_pages`` rebinding ``kv.k``/``kv.v``).
* **dtype** — unpinned index dtypes: ``jnp.arange`` without an explicit
  ``dtype`` (platform-dependent width; page-table indices must be
  ``jnp.int32``), ``jnp.asarray``/``jnp.array`` of a list literal
  without a dtype, and any explicit ``float64`` (promotion creep).
* **memspace-conflict** — assigning a host-tainted value to a
  device-annotated attribute (or vice versa).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from tools.analysis.common import (Finding, FunctionInfo, ModuleInfo,
                                   Package, annotation, annotation_span,
                                   attr_chain)

_NP_ROOTS = {"np", "numpy"}
_JNP_ROOTS = {"jnp", "jax"}
_D2H_CALLS = {("np", "asarray"), ("np", "array"), ("numpy", "asarray"),
              ("numpy", "array"), ("jax", "device_get")}
_H2D_CALLS = {("jnp", "asarray"), ("jnp", "array")}
_HOST_METHODS = {"tolist", "item"}


def _is_jit_value(value: ast.AST) -> bool:
    """``jax.jit(...)`` or ``functools.partial(jax.jit, ...)``."""
    if not isinstance(value, ast.Call):
        return False
    chain = attr_chain(value.func)
    if chain and chain[-1] == "jit":
        return True
    if chain and chain[-1] == "partial" and value.args:
        inner = attr_chain(value.args[0])
        return bool(inner) and inner[-1] == "jit"
    return False


def _donated_positions(value: ast.Call,
                       local_assigns: Optional[Dict[str, ast.AST]] = None
                       ) -> Optional[Set[int]]:
    """Positions named by ``donate_argnums`` (ints collected from the
    whole expression, so ``(2, 3) if gpu else ()`` resolves to {2, 3};
    a bare name resolves through the enclosing function's assigns)."""
    kws = {k.arg: k.value for k in value.keywords}
    if _is_jit_value(value) and "donate_argnums" not in kws \
            and value.args and isinstance(value.args[0], ast.Call):
        kws = {k.arg: k.value for k in value.args[0].keywords} | kws
    expr = kws.get("donate_argnums")
    if isinstance(expr, ast.Name) and local_assigns:
        expr = local_assigns.get(expr.id, expr)
    if expr is None:
        return None
    return {n.value for n in ast.walk(expr)
            if isinstance(n, ast.Constant) and isinstance(n.value, int)}


class _Scope:
    """Per-module registries shared by every function check."""

    def __init__(self, pkg: Package, mod: ModuleInfo):
        self.pkg = pkg
        self.mod = mod
        # (ClassName|None, attr/fn name) -> "device"|"host"
        self.attr_space: Dict[Tuple[Optional[str], str], str] = {}
        # (ClassName|None, name) -> donated positions
        self.donate: Dict[Tuple[Optional[str], str], Set[int]] = {}
        self.jitted: Set[Tuple[Optional[str], str]] = set()
        self._collect()

    def _collect(self) -> None:
        mod = self.mod
        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._note_function(None, node)
            elif isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self._note_function(node.name, item)
                        local = {
                            s.targets[0].id: s.value
                            for s in ast.walk(item)
                            if isinstance(s, ast.Assign)
                            and len(s.targets) == 1
                            and isinstance(s.targets[0], ast.Name)}
                        for stmt in ast.walk(item):
                            self._note_assign(node.name, stmt, local)
                    elif isinstance(item, ast.Assign):
                        self._note_assign(node.name, item)

    def _note_function(self, cname, node) -> None:
        for deco in node.decorator_list:
            if _is_jit_value(deco) or (
                    attr_chain(deco) or ("",))[-1] == "jit":
                self.jitted.add((cname, node.name))
                if isinstance(deco, ast.Call):
                    pos = _donated_positions(deco)
                    if pos:
                        self.donate[(cname, node.name)] = pos

    def _note_assign(self, cname, stmt, local_assigns=None) -> None:
        if not isinstance(stmt, ast.Assign) or len(stmt.targets) != 1:
            return
        chain = attr_chain(stmt.targets[0])
        if chain is None or len(chain) != 2 or chain[0] != "self":
            return
        attr = chain[1]
        space = annotation_span(self.mod, stmt, "memspace")
        if space is not None:
            word = space.split()[0] if space.split() else ""
            if word in ("device", "host"):
                self.attr_space[(cname, attr)] = word
        if isinstance(stmt.value, ast.Call):
            if _is_jit_value(stmt.value):
                self.jitted.add((cname, attr))
                pos = _donated_positions(stmt.value, local_assigns)
                if pos:
                    self.donate[(cname, attr)] = pos

    # class methods that rebind ``self.<attr>`` — used to clear
    # use-after-donate poison at ``obj.method(...)`` call sites
    def rebinds(self, cls: str, method: str) -> Set[str]:
        ci = self.pkg.classes.get(cls)
        if ci is None or method not in ci.methods:
            return set()
        out: Set[str] = set()
        for stmt in ast.walk(ci.methods[method].node):
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    ch = attr_chain(tgt)
                    if ch and len(ch) == 2 and ch[0] == "self":
                        out.add(ch[1])
        return out


class _FnCheck:
    """Taint walk of one function, in statement order."""

    def __init__(self, scope: _Scope, fi: FunctionInfo,
                 findings: List[Finding]):
        self.scope = scope
        self.mod = scope.mod
        self.fi = fi
        self.findings = findings
        self.env: Dict[str, str] = {}
        self.poison: Dict[str, int] = {}      # donated expr -> donate line
        self.loop_depth = 0
        self.stmt: Optional[ast.stmt] = None
        self.local_types = scope.pkg.local_types_for(fi)
        note = annotation(self.mod, fi.node.lineno, "memspace")
        self.staging = note is not None and note.split()[:1] == ["staging"]

    def flag(self, node, symbol, msg) -> None:
        if annotation_span(self.mod, self.stmt or node,
                           "not-a-transfer"):
            return
        self.findings.append(Finding(
            "devmem", self.mod.rel, node.lineno, self.fi.qualname,
            symbol, msg))

    # ------------------------------------------------------------ taint
    def taint(self, e: ast.AST) -> Optional[str]:
        if isinstance(e, (ast.List, ast.ListComp)):
            return "host"            # dicts may hold device arrays
        if isinstance(e, ast.Name):
            return self.env.get(e.id)
        if isinstance(e, ast.Attribute):
            chain = attr_chain(e)
            if chain:
                owner = self._owner(chain)
                if owner:
                    return self.scope.attr_space.get(owner)
            return None
        if isinstance(e, ast.Subscript):
            return self.taint(e.value)
        if isinstance(e, ast.BinOp):
            l, r = self.taint(e.left), self.taint(e.right)
            if "device" in (l, r):
                return "device"
            if "host" in (l, r):
                return "host"
            return None
        if isinstance(e, ast.UnaryOp):
            return self.taint(e.operand)
        if isinstance(e, ast.IfExp):
            a, b = self.taint(e.body), self.taint(e.orelse)
            return a if a == b else None
        if isinstance(e, ast.Call):
            return self._call_taint(e)
        return None

    def _owner(self, chain) -> Optional[Tuple[Optional[str], str]]:
        ci = self.scope.pkg.classes.get(self.fi.cls) if self.fi.cls \
            else None
        got = self.scope.pkg.class_of_chain(ci, chain, self.local_types)
        if got:
            return got
        if len(chain) == 1:
            return (None, chain[0])
        return None

    def _call_taint(self, e: ast.Call) -> Optional[str]:
        chain = attr_chain(e.func)
        if chain is None:
            if isinstance(e.func, ast.Attribute) \
                    and e.func.attr in _HOST_METHODS:
                return "host"
            return None
        if chain[-1] in _HOST_METHODS or chain[-1] in ("int", "float") \
                and len(chain) == 1:
            return "host"
        if tuple(chain[-2:]) == ("jax", "device_get"):
            return "host"
        if chain[0] in _NP_ROOTS:
            return "host"
        if chain[0] in _JNP_ROOTS:
            return "device"
        key = self._callee_key(chain)
        if key in self.scope.jitted:
            return "device"
        return None

    def _callee_key(self, chain) -> Tuple[Optional[str], str]:
        if len(chain) == 1:
            return (None, chain[0])
        if chain[0] == "self" and len(chain) == 2:
            return (self.fi.cls, chain[1])
        return (None, "")

    # ------------------------------------------------------- statements
    def run(self) -> None:
        self._stmts(self.fi.node.body)

    def _stmts(self, body) -> None:
        for stmt in body:
            self.stmt = stmt
            self._stmt(stmt)

    def _stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return                       # nested defs: out of scope
        if isinstance(s, ast.Assign):
            self._check_expr(s.value)
            taint = self.taint(s.value)
            self._apply_call_effects(s.value)
            for tgt in s.targets:
                self._bind(tgt, s.value, taint)
            return
        if isinstance(s, ast.AugAssign):
            self._check_expr(s.value)
            self._check_load(s.target)
            return
        if isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self._check_expr(s.value)
                self._bind(s.target, s.value, self.taint(s.value))
            return
        if isinstance(s, ast.Expr):
            self._check_expr(s.value)
            self._apply_call_effects(s.value)
            return
        if isinstance(s, ast.Return):
            if s.value is not None:
                self._check_expr(s.value)
            return
        if isinstance(s, (ast.For, ast.AsyncFor)):
            self._check_expr(s.iter)
            self.loop_depth += 1
            self._stmts(s.body)
            self.loop_depth -= 1
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.While):
            self._check_expr(s.test)
            self.loop_depth += 1
            self._stmts(s.body)
            self.loop_depth -= 1
            self._stmts(s.orelse)
            return
        if isinstance(s, ast.If):
            self._check_expr(s.test)
            self._stmts(s.body)
            self._stmts(s.orelse)
            return
        if isinstance(s, (ast.With, ast.AsyncWith)):
            for item in s.items:
                self._check_expr(item.context_expr)
            self._stmts(s.body)
            return
        if isinstance(s, ast.Try):
            self._stmts(s.body)
            for h in s.handlers:
                self._stmts(h.body)
            self._stmts(s.orelse)
            self._stmts(s.finalbody)
            return
        for child in ast.iter_child_nodes(s):
            if isinstance(child, ast.expr):
                self._check_expr(child)

    def _bind(self, tgt, value, taint) -> None:
        if isinstance(tgt, ast.Name):
            if taint:
                self.env[tgt.id] = taint
            else:
                self.env.pop(tgt.id, None)
        elif isinstance(tgt, ast.Tuple):
            for el in tgt.elts:
                self._bind(el, value, taint)
        elif isinstance(tgt, (ast.Attribute, ast.Subscript)):
            self._check_space_conflict(tgt, taint)
        # any rebinding clears use-after-donate poison for that expr
        try:
            self.poison.pop(ast.unparse(tgt), None)
        except Exception:
            pass

    def _check_space_conflict(self, tgt, taint) -> None:
        chain = attr_chain(tgt)
        if chain is None or taint is None:
            return
        owner = self._owner(chain)
        if owner is None:
            return
        declared = self.scope.attr_space.get(owner)
        if declared and declared != taint:
            self.flag(tgt, "memspace-conflict",
                      f"{'.'.join(chain)} is annotated "
                      f"'# memspace: {declared}' but is assigned a "
                      f"{taint}-tainted value")

    # ------------------------------------------------- expression rules
    def _check_expr(self, e: ast.AST) -> None:
        for node in ast.walk(e):
            if isinstance(node, ast.Call):
                self._check_call(node)
            elif isinstance(node, (ast.Name, ast.Attribute)) \
                    and isinstance(getattr(node, "ctx", None), ast.Load):
                self._check_poisoned(node)

    def _check_load(self, e: ast.AST) -> None:
        for node in ast.walk(e):
            if isinstance(node, (ast.Name, ast.Attribute)):
                self._check_poisoned(node)

    def _check_poisoned(self, node) -> None:
        try:
            key = ast.unparse(node)
        except Exception:
            return
        line = self.poison.get(key)
        if line is not None:
            self.flag(node, "use-after-donate",
                      f"{key} was donated to a donate_argnums jit on "
                      f"line {line} and read before being rebound — "
                      "the donated buffer is invalid")

    def _check_call(self, call: ast.Call) -> None:
        chain = attr_chain(call.func)
        if not chain:
            return
        tail2 = tuple(chain[-2:]) if len(chain) >= 2 else ()
        if tail2 in _D2H_CALLS and call.args \
                and self.taint(call.args[0]) == "device" \
                and not self.staging:
            self.flag(call, "d2h",
                      f"implicit device->host transfer: "
                      f"{'.'.join(chain)}() on a device-resident value "
                      "outside a '# memspace: staging' function — each "
                      "one is a blocking sync; hoist it to a staging "
                      "boundary or note '# not-a-transfer: <reason>'")
        if tail2 in _H2D_CALLS and self.loop_depth > 0 and call.args \
                and self.taint(call.args[0]) == "host":
            self.flag(call, "h2d-loop",
                      "host->device upload inside a loop: "
                      f"{'.'.join(chain)}() re-uploads per iteration — "
                      "hoist or batch the transfer")
        self._check_dtype(call, chain, tail2)

    def _check_dtype(self, call, chain, tail2) -> None:
        kws = {k.arg for k in call.keywords}
        if tail2 == ("jnp", "arange") and "dtype" not in kws \
                and len(call.args) < 4:
            self.flag(call, "dtype",
                      "jnp.arange without an explicit dtype: index "
                      "width is platform-dependent — pin index/page "
                      "arithmetic to jnp.int32")
        if tail2 in _H2D_CALLS and call.args \
                and isinstance(call.args[0], (ast.List, ast.ListComp)) \
                and "dtype" not in kws and len(call.args) < 2:
            self.flag(call, "dtype",
                      f"{'.'.join(chain)}() of a Python list without an "
                      "explicit dtype — the inferred width is "
                      "platform-dependent; pin it")
        for node in ast.walk(call):
            ch = attr_chain(node) if isinstance(node, ast.Attribute) \
                else None
            if ch and ch[-1] == "float64" \
                    and ch[0] in _NP_ROOTS | _JNP_ROOTS:
                self.flag(node, "dtype",
                          "explicit float64: f64 promotion creep — the "
                          "engine is pinned to f32/bf16 arithmetic")

    # ------------------------------------------------------ call effects
    def _apply_call_effects(self, e: ast.AST) -> None:
        for call in [n for n in ast.walk(e) if isinstance(n, ast.Call)]:
            chain = attr_chain(call.func)
            if not chain:
                continue
            key = self._callee_key(chain)
            donated = self.scope.donate.get(key)
            if donated:
                for pos in sorted(donated):
                    if pos < len(call.args):
                        arg = call.args[pos]
                        if isinstance(arg, (ast.Name, ast.Attribute)):
                            self.poison[ast.unparse(arg)] = call.lineno
                continue
            # obj.method(...) where the method rebinds self.<attr>
            # clears poison for obj.<attr>
            if len(chain) >= 2 and self.poison:
                recv = ".".join(chain[:-1])
                cls = self._receiver_class(chain[:-1])
                if cls:
                    for attr in self.scope.rebinds(cls, chain[-1]):
                        self.poison.pop(f"{recv}.{attr}", None)

    def _receiver_class(self, chain) -> Optional[str]:
        if len(chain) == 1:
            return self.local_types.get(chain[0])
        if chain[0] == "self" and len(chain) == 2 and self.fi.cls:
            ci = self.scope.pkg.classes.get(self.fi.cls)
            if ci:
                return ci.attr_types.get(chain[1])
        return None


def _in_scope(mod: ModuleInfo) -> bool:
    return any(kw == "memspace"
               for pairs in mod.annotations.values()
               for kw, _ in pairs)


def check_devmem(pkg: Package) -> List[Finding]:
    """Entry point: all memory-discipline findings for a package."""
    findings: List[Finding] = []
    for mod in pkg.modules.values():
        if not _in_scope(mod):
            continue
        scope = _Scope(pkg, mod)
        fns: List[FunctionInfo] = list(mod.functions.values())
        for cname in mod.classes:
            fns.extend(pkg.classes[cname].methods.values())
        for fi in fns:
            _FnCheck(scope, fi, findings).run()
    return findings


def count_devmem(pkg: Package) -> Tuple[int, int]:
    """(memspace-annotated attrs/modules, donate-jit sites)."""
    n_attrs = 0
    n_donate = 0
    for mod in pkg.modules.values():
        if not _in_scope(mod):
            continue
        scope = _Scope(pkg, mod)
        n_attrs += len(scope.attr_space)
        n_donate += len(scope.donate)
    return n_attrs, n_donate
