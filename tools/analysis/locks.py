"""Lock-discipline checker (DESIGN.md §11).

Two obligations, both driven by the annotation grammar in ``common``:

1. **Guarded access** — every read/write of an attribute declared
   ``# guarded-by: <lock> | <thread>`` must happen while one of the
   alternatives holds: lexically inside ``with <lock>:`` (or after a
   tracked ``.acquire()``), in a method annotated/propagated
   ``# runs-on: <thread>``, or in a method whose ``# requires:``
   contract is a subset of the attribute's alternatives (the caller
   already guaranteed one of them).  ``# swap-only`` attributes are
   exempt from locking but may only be rebound whole — in-place
   mutation (augmented assignment, subscript store, ``.append``-class
   methods) is flagged.

2. **Acquisition order** — every "acquire B while holding A" site adds
   an A→B edge, including transitively through resolvable callees; a
   cycle in the resulting cross-module graph (or a self-edge on a
   non-reentrant lock) is a deadlock the runtime verifier
   (``repro.debugsync``) would eventually hit under the right timing,
   so it fails the build now.
"""
from __future__ import annotations

import ast
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from tools.analysis.common import (Finding, FunctionInfo, Package,
                                   attr_chain)

_MUTATORS = {"append", "add", "update", "pop", "clear", "extend",
             "remove", "discard", "setdefault", "insert", "popitem"}
_EXEMPT_METHODS = {"__init__", "__post_init__"}


def _satisfied(alts: Set[str], held: Set[str],
               fi: FunctionInfo) -> bool:
    if held & alts:
        return True
    if fi.runs_on is not None and fi.runs_on in alts:
        return True
    if fi.requires and fi.requires <= alts:
        return True
    return False


class _FunctionWalk:
    """Walks one function body tracking lexically-held locks."""

    def __init__(self, checker: "LockChecker", fi: FunctionInfo) -> None:
        self.c = checker
        self.pkg = checker.pkg
        self.fi = fi
        self.ci = self.pkg.classes.get(fi.cls) if fi.cls else None
        self.local_types = self.pkg.local_types_for(fi)
        self.local_locks = self._find_local_locks(fi.node)
        self.init_held = frozenset(
            a for a in fi.requires if "." in a)

    def _find_local_locks(self, node) -> Dict[str, str]:
        out: Dict[str, str] = {}
        for stmt in ast.walk(node):
            if not (isinstance(stmt, ast.Assign)
                    and len(stmt.targets) == 1
                    and isinstance(stmt.targets[0], ast.Name)
                    and isinstance(stmt.value, ast.Call)):
                continue
            chain = attr_chain(stmt.value.func)
            if not chain:
                continue
            name = stmt.targets[0].id
            if chain[-1] in ("named_lock", "named_condition"):
                arg = stmt.value.args[0] if stmt.value.args else None
                if isinstance(arg, ast.Constant):
                    out[name] = str(arg.value)
            elif chain[-1] in ("Lock", "RLock", "Condition") and (
                    len(chain) == 1 or chain[0] == "threading"):
                out[name] = f"{self.fi.qualname}.{name}"
        return out

    def lock_of(self, expr: ast.AST) -> Optional[str]:
        chain = attr_chain(expr)
        if chain is None:
            return None
        if len(chain) == 1 and chain[0] in self.local_locks:
            return self.local_locks[chain[0]]
        return self.pkg.lock_of_chain(self.ci, chain, self.local_types)

    # -- statement walking ----------------------------------------
    def run(self) -> None:
        self.walk_block(self.fi.node.body, set(self.init_held))

    def walk_block(self, stmts: List[ast.stmt],
                   held: Set[str]) -> Set[str]:
        held = set(held)
        for stmt in stmts:
            held = self.walk_stmt(stmt, held)
        return held

    def _acquire(self, lock: str, held: Set[str], lineno: int) -> None:
        self.c.note_acquire(self.fi, lock, frozenset(held), lineno)

    def _acq_rel_calls(self, stmt: ast.stmt) -> Tuple[List, List]:
        """(acquire, release) lock-call sites inside a statement's
        expressions (``X.acquire(...)`` / ``X.release()``)."""
        acq, rel = [], []
        for e in self._stmt_exprs(stmt):
            for sub in ast.walk(e):
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr in ("acquire", "release"):
                    lock = self.lock_of(sub.func.value)
                    if lock is not None:
                        (acq if sub.func.attr == "acquire"
                         else rel).append((lock, sub.lineno))
        return acq, rel

    @staticmethod
    def _stmt_exprs(stmt: ast.stmt):
        for _field, value in ast.iter_fields(stmt):
            vals = value if isinstance(value, list) else [value]
            for v in vals:
                if isinstance(v, ast.expr):
                    yield v

    def walk_stmt(self, stmt: ast.stmt, held: Set[str]) -> Set[str]:
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            acquired: List[str] = []
            for item in stmt.items:
                lock = self.lock_of(item.context_expr)
                if lock is not None:
                    self._acquire(lock, held | set(acquired),
                                  stmt.lineno)
                    acquired.append(lock)
                else:
                    self.scan_expr(item.context_expr, held)
            self.walk_block(stmt.body, held | set(acquired))
            return held
        if isinstance(stmt, ast.Try):
            after = self.walk_block(stmt.body, held)
            for handler in stmt.handlers:
                self.walk_block(handler.body, held)
            after = self.walk_block(stmt.orelse, after)
            _acq, rel = [], []
            for s in stmt.finalbody:
                a, r = self._acq_rel_calls(s)
                rel.extend(r)
            self.walk_block(stmt.finalbody, after)
            for lock, _ln in rel:
                after.discard(lock)
            return after
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # closures run later but share the lexical lock scope often
            # enough (wait_for predicates, nested publish helpers) that
            # the enclosing held-set is the useful approximation.
            self.walk_block(stmt.body, held)
            return held
        if isinstance(stmt, ast.ClassDef):
            return held

        acq, rel = self._acq_rel_calls(stmt)
        for e in self._stmt_exprs(stmt):
            self.scan_expr(e, held)
        if isinstance(stmt, (ast.If, ast.While)):
            body_held = set(held)
            if isinstance(stmt, ast.While):
                for lock, ln in acq:   # `while not X.acquire():` spin
                    self._acquire(lock, held, ln)
            self.walk_block(stmt.body, body_held)
            self.walk_block(stmt.orelse, set(held))
        elif isinstance(stmt, ast.For):
            self.walk_block(stmt.body, set(held))
            self.walk_block(stmt.orelse, set(held))
        elif isinstance(stmt, ast.Match):
            for case in stmt.cases:
                self.walk_block(case.body, set(held))
        after = set(held)
        if isinstance(stmt, ast.If) and acq and stmt.body \
                and isinstance(stmt.body[-1],
                               (ast.Continue, ast.Return, ast.Raise,
                                ast.Break)):
            # `if not lock.acquire(blocking=False): <bail>` — after the
            # If, the lock is held on the fall-through path.
            for lock, ln in acq:
                self._acquire(lock, held, ln)
                after.add(lock)
        elif acq and not isinstance(stmt, (ast.If, ast.While)):
            for lock, ln in acq:
                self._acquire(lock, held, ln)
                after.add(lock)
        for lock, _ln in rel:
            after.discard(lock)
        return after

    # -- expression scanning --------------------------------------
    def scan_expr(self, expr: ast.expr, held: Set[str]) -> None:
        if expr is None:
            return
        for sub in ast.walk(expr):
            if isinstance(sub, ast.Attribute):
                self._check_attr(sub, held)
            elif isinstance(sub, ast.Call):
                self._check_call(sub, held)
        # in-place mutation of swap-only attrs via statements is
        # handled here too: the Attribute check sees ctx flags.

    def _resolve_owner(self, node: ast.Attribute) -> \
            Optional[Tuple[str, str]]:
        chain = attr_chain(node)
        if chain is None:
            return None
        return self.pkg.class_of_chain(self.ci, chain, self.local_types)

    def _check_attr(self, node: ast.Attribute, held: Set[str]) -> None:
        owner = self._resolve_owner(node)
        if owner is None:
            return
        cname, attr = owner
        oci = self.pkg.classes.get(cname)
        if oci is None:
            return
        if attr in oci.swap_only:
            return  # stores checked via _check_swap_stmt
        alts = oci.guarded.get(attr)
        if not alts:
            return
        if self.fi.name in _EXEMPT_METHODS:
            return
        if _satisfied(alts, held, self.fi):
            return
        self.c.findings.append(Finding(
            "locks", self.fi.module, node.lineno, self.fi.qualname, attr,
            f"access to {cname}.{attr} (guarded-by "
            f"{' | '.join(sorted(alts))}) outside any alternative "
            f"(held: {sorted(held) or 'nothing'}, "
            f"runs-on: {self.fi.runs_on or '?'})"))

    def _check_call(self, call: ast.Call, held: Set[str]) -> None:
        # swap-only in-place mutators: obj.attr.append(...)
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _MUTATORS \
                and isinstance(call.func.value, ast.Attribute):
            owner = self._resolve_owner(call.func.value)
            if owner is not None:
                cname, attr = owner
                oci = self.pkg.classes.get(cname)
                if oci is not None and attr in oci.swap_only:
                    self.c.findings.append(Finding(
                        "locks", self.fi.module, call.lineno,
                        self.fi.qualname, attr,
                        f"{cname}.{attr} is swap-only but "
                        f".{call.func.attr}() mutates it in place"))
        mod = self.pkg.modules.get(self.fi.module)
        callee = self.pkg.resolve_callee(mod, self.fi, call,
                                         self.local_types)
        if callee is None:
            return
        self.c.note_call(self.fi, callee, frozenset(held), call.lineno)
        if callee.requires and callee.name not in _EXEMPT_METHODS:
            if not _satisfied(callee.requires, held, self.fi):
                self.c.findings.append(Finding(
                    "locks", self.fi.module, call.lineno,
                    self.fi.qualname, callee.name,
                    f"call to {callee.qualname} (requires "
                    f"{' | '.join(sorted(callee.requires))}) without "
                    f"satisfying the contract (held: "
                    f"{sorted(held) or 'nothing'})"))

    def check_swap_stores(self) -> None:
        """AugAssign / subscript-store on swap-only attrs."""
        for stmt in ast.walk(self.fi.node):
            if isinstance(stmt, ast.AugAssign):
                tgt = stmt.target
                node = tgt.value if isinstance(
                    tgt, ast.Subscript) else tgt
                if isinstance(node, ast.Attribute):
                    self._flag_swap(node, stmt.lineno, "augmented-assign")
            elif isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Subscript) \
                            and isinstance(tgt.value, ast.Attribute):
                        self._flag_swap(tgt.value, stmt.lineno,
                                        "subscript-store")

    def _flag_swap(self, node: ast.Attribute, lineno: int,
                   how: str) -> None:
        owner = self._resolve_owner(node)
        if owner is None:
            return
        cname, attr = owner
        oci = self.pkg.classes.get(cname)
        if oci is not None and attr in oci.swap_only:
            self.c.findings.append(Finding(
                "locks", self.fi.module, lineno, self.fi.qualname, attr,
                f"{cname}.{attr} is swap-only but {how} mutates it "
                f"in place (rebind a fresh object instead)"))


class LockChecker:
    """Runs the discipline walk over every function, then the order
    graph."""

    def __init__(self, pkg: Package) -> None:
        self.pkg = pkg
        self.findings: List[Finding] = []
        # (a, b) -> example "file:line in qualname"
        self.edges: Dict[Tuple[str, str], str] = {}
        self.direct_acquires: Dict[str, Set[str]] = {}
        self.calls: List[Tuple[FunctionInfo, FunctionInfo,
                               FrozenSet[str], int]] = []

    def note_acquire(self, fi: FunctionInfo, lock: str,
                     held: FrozenSet[str], lineno: int) -> None:
        self.direct_acquires.setdefault(fi.qualname, set()).add(lock)
        site = f"{fi.module}:{lineno} in {fi.qualname}"
        for h in held:
            if h == lock:
                self.findings.append(Finding(
                    "locks", fi.module, lineno, fi.qualname, lock,
                    f"re-acquisition of {lock} while already held "
                    f"(self-deadlock on a non-reentrant lock)"))
            else:
                self.edges.setdefault((h, lock), site)

    def note_call(self, caller: FunctionInfo, callee: FunctionInfo,
                  held: FrozenSet[str], lineno: int) -> None:
        self.calls.append((caller, callee, held, lineno))

    # -- transitive acquisition closure ---------------------------
    def _acquires_star(self) -> Dict[str, Set[str]]:
        star = {q: set(s) for q, s in self.direct_acquires.items()}
        callees: Dict[str, Set[str]] = {}
        for caller, callee, _held, _ln in self.calls:
            callees.setdefault(caller.qualname, set()).add(
                callee.qualname)
        changed = True
        while changed:
            changed = False
            for q, cs in callees.items():
                cur = star.setdefault(q, set())
                for c in cs:
                    extra = star.get(c, set()) - cur
                    if extra:
                        cur |= extra
                        changed = True
        return star

    def run(self) -> List[Finding]:
        for fi in self.pkg.all_functions():
            walk = _FunctionWalk(self, fi)
            walk.run()
            walk.check_swap_stores()
        star = self._acquires_star()
        for caller, callee, held, lineno in self.calls:
            if not held:
                continue
            site = f"{caller.module}:{lineno} in {caller.qualname} " \
                   f"-> {callee.qualname}"
            for lock in star.get(callee.qualname, ()):  # noqa: B007
                for h in held:
                    if h != lock:
                        self.edges.setdefault((h, lock), site)
                    # held-reentry through a callee is caught at the
                    # callee's own acquire site; no self-edge here —
                    # requires-annotated callees legitimately re-state
                    # the already-held lock.
        self._check_cycles()
        return self.findings

    def _check_cycles(self) -> None:
        succ: Dict[str, Set[str]] = {}
        for (a, b) in self.edges:
            succ.setdefault(a, set()).add(b)
        # DFS with path reconstruction
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in
                 set(succ) | {b for bs in succ.values() for b in bs}}
        path: List[str] = []

        def dfs(n: str) -> Optional[List[str]]:
            color[n] = GRAY
            path.append(n)
            for m in sorted(succ.get(n, ())):
                if color[m] == GRAY:
                    return path[path.index(m):] + [m]
                if color[m] == WHITE:
                    cyc = dfs(m)
                    if cyc:
                        return cyc
            color[n] = BLACK
            path.pop()
            return None

        for n in sorted(color):
            if color[n] == WHITE:
                path.clear()
                cyc = dfs(n)
                if cyc:
                    hops = []
                    for a, b in zip(cyc, cyc[1:]):
                        hops.append(f"{a} -> {b} "
                                    f"[{self.edges.get((a, b), '?')}]")
                    self.findings.append(Finding(
                        "locks", "<graph>", 0, "lock-order",
                        "cycle",
                        "lock acquisition-order cycle: "
                        + "; ".join(hops)))
                    return


def check_locks(pkg: Package) -> List[Finding]:
    """Entry point: all lock-discipline findings for a package."""
    return LockChecker(pkg).run()


def order_edges(pkg: Package) -> Dict[Tuple[str, str], str]:
    """The static acquisition-order edge set (for diagnostics/tests)."""
    c = LockChecker(pkg)
    c.run()
    return dict(c.edges)
