"""Repo-specific static analysis (DESIGN.md §11).

Three AST checkers over ``src/repro``:

* ``locks``    — guarded-attribute discipline + lock-order graph
* ``jit``      — jax.jit declaration/tracer-branch/bucketing hazards
* ``hostsync`` — device→host syncs reachable from the engine step loop

Run locally from the repo root::

    python -m tools.analysis --strict

``run()`` is the programmatic entry point the tests and the nightly
BENCH export use.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional, Tuple

from tools.analysis.common import Allowlist, AllowEntry, Finding, Package
from tools.analysis.hostsync import (DEFAULT_ROOTS, check_hostsync,
                                     hot_path_size)
from tools.analysis.jit import check_jit, count_jit_sites
from tools.analysis.locks import check_locks

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent.parent
DEFAULT_SRC = REPO_ROOT / "src" / "repro"
DEFAULT_ALLOWLIST = pathlib.Path(__file__).resolve().parent / \
    "allowlist.toml"


@dataclasses.dataclass
class Result:
    """Everything one analysis run produced."""

    findings: List[Finding]
    suppressed: List[Tuple[Finding, AllowEntry]]
    config_errors: List[Finding]
    allow_errors: List[str]
    unused: List[AllowEntry]
    counts: Dict[str, int]

    def ok(self, strict: bool = False) -> bool:
        if self.findings or self.config_errors or self.allow_errors:
            return False
        if strict and self.unused:
            return False
        return True


def run(root: Optional[pathlib.Path] = None,
        allowlist: Optional[pathlib.Path] = None,
        override: Optional[Dict[str, str]] = None,
        roots: Tuple[str, ...] = DEFAULT_ROOTS) -> Result:
    """Run all three checkers over ``root`` (default: src/repro)."""
    root = pathlib.Path(root) if root is not None else DEFAULT_SRC
    allow_path = allowlist if allowlist is not None else \
        DEFAULT_ALLOWLIST
    pkg = Package.load(root, override=override)
    allow = Allowlist.load(allow_path)
    raw = check_locks(pkg) + check_jit(pkg) \
        + check_hostsync(pkg, roots=roots)
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, AllowEntry]] = []
    for f in raw:
        e = allow.match(f)
        if e is not None:
            suppressed.append((f, e))
        else:
            kept.append(f)
    counts = {
        "named_locks": sum(len(c.locks) for c in pkg.classes.values()),
        "guarded_attrs": sum(len(c.guarded)
                             for c in pkg.classes.values()),
        "jit_sites": count_jit_sites(pkg),
        "hot_path_functions": hot_path_size(pkg, roots=roots),
        "syncs_allowed": sum(1 for f, e in suppressed
                             if f.checker == "hostsync"
                             and e.kind == "sync"),
        "suppressions": len(suppressed),
        "findings": len(kept),
    }
    return Result(findings=kept, suppressed=suppressed,
                  config_errors=list(pkg.config_errors),
                  allow_errors=list(allow.errors),
                  unused=allow.unused(), counts=counts)
