"""Repo-specific static analysis (DESIGN.md §11).

Six AST checkers over ``src/repro``:

* ``locks``    — guarded-attribute discipline + lock-order graph
* ``jit``      — jax.jit declaration/tracer-branch/bucketing hazards
* ``hostsync`` — device→host syncs reachable from the engine step loop
* ``devmem``   — device/host memory-space discipline (§11.4)
* ``kernel``   — Pallas kernel contracts: triples, BlockSpec
  divisibility, grid arity, VMEM budgets (§11.5)
* ``units``    — dimensional analysis over the cost model (§11.6)

Run locally from the repo root::

    python -m tools.analysis --strict

``run()`` is the programmatic entry point the tests and the nightly
BENCH export use.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional, Tuple

from tools.analysis.common import Allowlist, AllowEntry, Finding, Package
from tools.analysis.devmem import check_devmem, count_devmem
from tools.analysis.hostsync import (DEFAULT_ROOTS, check_hostsync,
                                     hot_path_size)
from tools.analysis.jit import check_jit, count_jit_sites
from tools.analysis.kernelcheck import check_kernels, count_kernels
from tools.analysis.locks import check_locks
from tools.analysis.units import check_units, count_units

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent.parent
DEFAULT_SRC = REPO_ROOT / "src" / "repro"
DEFAULT_ALLOWLIST = pathlib.Path(__file__).resolve().parent / \
    "allowlist.toml"
DEFAULT_KERNEL_TESTS = REPO_ROOT / "tests" / "test_kernels.py"

CHECKERS = ("locks", "jit", "hostsync", "devmem", "kernel", "units")


@dataclasses.dataclass
class Result:
    """Everything one analysis run produced."""

    findings: List[Finding]
    suppressed: List[Tuple[Finding, AllowEntry]]
    config_errors: List[Finding]
    allow_errors: List[str]
    unused: List[AllowEntry]
    counts: Dict[str, int]

    def ok(self, strict: bool = False) -> bool:
        if self.findings or self.config_errors or self.allow_errors:
            return False
        if strict and self.unused:
            return False
        return True


def run(root: Optional[pathlib.Path] = None,
        allowlist: Optional[pathlib.Path] = None,
        override: Optional[Dict[str, str]] = None,
        roots: Tuple[str, ...] = DEFAULT_ROOTS,
        only: Optional[Tuple[str, ...]] = None) -> Result:
    """Run the checkers over ``root`` (default: src/repro).

    ``only`` restricts to a subset of :data:`CHECKERS` — the allowlist
    and counts still cover every checker, but unused-entry strictness
    is waived for the checkers that did not run.
    """
    root = pathlib.Path(root) if root is not None else DEFAULT_SRC
    allow_path = allowlist if allowlist is not None else \
        DEFAULT_ALLOWLIST
    active = tuple(only) if only else CHECKERS
    pkg = Package.load(root, override=override)
    allow = Allowlist.load(allow_path)
    # the parity-test cross-reference only makes sense for the real
    # tree; fixture packages are not expected in tests/test_kernels.py
    tests_source: Optional[str] = None
    if root == DEFAULT_SRC and DEFAULT_KERNEL_TESTS.is_file():
        tests_source = DEFAULT_KERNEL_TESTS.read_text(encoding="utf-8")
    raw: List[Finding] = []
    if "locks" in active:
        raw += check_locks(pkg)
    if "jit" in active:
        raw += check_jit(pkg)
    if "hostsync" in active:
        raw += check_hostsync(pkg, roots=roots)
    if "devmem" in active:
        raw += check_devmem(pkg)
    if "kernel" in active:
        raw += check_kernels(pkg, tests_source)
    if "units" in active:
        raw += check_units(pkg)
    kept: List[Finding] = []
    suppressed: List[Tuple[Finding, AllowEntry]] = []
    for f in raw:
        e = allow.match(f)
        if e is not None:
            suppressed.append((f, e))
        else:
            kept.append(f)
    n_memspace, n_donate = count_devmem(pkg)
    n_kernels, n_blockspecs, n_budgets = count_kernels(pkg)
    n_unit_fields, n_unit_fns = count_units(pkg)
    counts = {
        "named_locks": sum(len(c.locks) for c in pkg.classes.values()),
        "guarded_attrs": sum(len(c.guarded)
                             for c in pkg.classes.values()),
        "jit_sites": count_jit_sites(pkg),
        "hot_path_functions": hot_path_size(pkg, roots=roots),
        "syncs_allowed": sum(1 for f, e in suppressed
                             if f.checker == "hostsync"
                             and e.kind == "sync"),
        "memspace_attrs": n_memspace,
        "donate_sites": n_donate,
        "budgeted_transfers": sum(1 for f, e in suppressed
                                  if f.checker == "devmem"
                                  and e.kind == "transfer"),
        "kernels_checked": n_kernels,
        "blockspecs_checked": n_blockspecs,
        "vmem_budgets": n_budgets,
        "unit_fields": n_unit_fields,
        "unit_functions": n_unit_fns,
        "suppressions": len(suppressed),
        "findings": len(kept),
    }
    # an allowlist entry for a checker that did not run can't be used —
    # don't let a partial run fail strict mode over it
    unused = [e for e in allow.unused()
              if e.checker in ("*",) + active] if only else \
        allow.unused()
    return Result(findings=kept, suppressed=suppressed,
                  config_errors=list(pkg.config_errors),
                  allow_errors=list(allow.errors),
                  unused=unused, counts=counts)
