"""Pure-jnp oracle for GQA flash-decode (single query position)."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, *, q_positions, kv_positions, window=0,
                         return_lse=False):
    """q: (B,H,Dh) one new token; k,v: (B,T,Hkv,Dh); kv_positions (B,T).

    Returns out (B,H,Dh); with return_lse also (m, l) each (B,H) — the
    running max and sum used for cross-chunk / cross-pass LSE combines.
    """
    B, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(Dh)
    qp = q_positions.reshape(B)[:, None, None, None]
    kp = kv_positions[:, None, None, :]
    mask = (kp >= 0) & (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask, logits, NEG_INF)
    m = logits.max(axis=-1)                                  # (B,Hkv,G)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, k * 0 + v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.where(l == 0.0, 1.0, l)[..., None]
    out = out.reshape(B, H, Dh).astype(q.dtype)
    if return_lse:
        return out, m.reshape(B, H), l.reshape(B, H)
    return out


def lse_combine(parts):
    """Combine [(out_i (B,H,Dh) f32-safe, m_i (B,H), l_i (B,H))] partials."""
    m = jnp.stack([p[1] for p in parts]).max(axis=0)         # (B,H)
    num = 0.0
    den = 0.0
    for out_i, m_i, l_i in parts:
        w = jnp.exp(m_i - m) * l_i                           # (B,H)
        num = num + out_i.astype(jnp.float32) * w[..., None]
        den = den + w
    den = jnp.where(den == 0.0, 1.0, den)
    return (num / den[..., None]).astype(parts[0][0].dtype)
