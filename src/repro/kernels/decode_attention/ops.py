"""Public jit'd wrapper for GQA flash-decode."""
from __future__ import annotations

import functools

import jax

from repro.kernels import env_interpret

from repro.kernels.decode_attention.kernel import decode_attention_kernel



def _pick_block(s: int, target: int) -> int:
    if s % target == 0:
        return target
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=(
    "window", "block_t", "return_lse", "interpret"))
def _decode_attention_jit(q, k, v, *, q_positions, kv_positions, window=0,
                          block_t=1024, return_lse=False, interpret=False):
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1
        q = q[:, 0]
    bt = _pick_block(k.shape[1], block_t)
    out, m, l = decode_attention_kernel(
        q, k, v, q_positions, kv_positions, window=window, block_t=bt,
        interpret=interpret)
    if squeeze:
        out = out[:, None]
    if return_lse:
        return out, m, l
    return out


def decode_attention(q, k, v, *, q_positions, kv_positions, window=0,
                     block_t=1024, return_lse=False, interpret=False):
    """q: (B,1,H,Dh) or (B,H,Dh). Returns same rank as q (plus lse).

    ``interpret`` is resolved against REPRO_PALLAS_INTERPRET before the
    jit boundary so the env override is part of the jit cache key.
    """
    return _decode_attention_jit(
        q, k, v, q_positions=q_positions, kv_positions=kv_positions,
        window=window, block_t=block_t, return_lse=return_lse,
        interpret=env_interpret(interpret))
