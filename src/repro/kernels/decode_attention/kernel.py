"""GQA flash-decode — Pallas TPU kernel.

Decode attention is HBM-bandwidth-bound: each step streams the whole KV
cache once.  The kernel tiles KV into VMEM chunks — grid (B, Hkv, n_t),
the KV-chunk dim innermost — and keeps the online-softmax state for all
G = H/Hkv query heads of one KV head in VMEM scratch, so each KV byte is
read exactly once per step (roofline-optimal for the memory term).

The optional (m, l) outputs expose the log-sum-exp state for combining
partial results across KV shards (shard_map flash-decoding, see
distribution/collectives.py) or across the shared-prefix/suffix split
(shared_prefix_attention).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, finalize_online_softmax,
                                  online_softmax_update, qk_logits)


def _decode_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref,
                   o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, window: int, n_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bt, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0]                                       # scalar int32
    kp = kp_ref[0, :]                                    # (bt,)

    logits = qk_logits(q, k, scale)                      # (G, bt)

    mask = (kp >= 0) & (kp <= qp)
    if window > 0:
        mask = mask & (kp > qp - window)

    acc_ref[...], m_ref[:, 0], l_ref[:, 0] = online_softmax_update(
        logits, mask[None, :], v, acc_ref[...], m_ref[:, 0], l_ref[:, 0])

    @pl.when(it == n_t - 1)
    def _done():
        out, m, l = finalize_online_softmax(
            acc_ref[...], m_ref[:, 0], l_ref[:, 0])
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)
        m_out_ref[0, 0, :, 0] = m
        l_out_ref[0, 0, :, 0] = l

# vmem-budget: 1.5 MiB @ block_t=1024 T=4096 Dh=128 H=32 Hkv=8
def decode_attention_kernel(q, k, v, q_positions, kv_positions, *,
                            window: int, block_t: int,
                            interpret: bool = False):
    """q: (B,H,Dh); k,v: (B,T,Hkv,Dh); T % block_t == 0.

    Returns (out (B,H,Dh), m (B,H), l (B,H)).
    """
    B, H, Dh = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bt = min(block_t, T)
    assert T % bt == 0
    n_t = T // bt
    grid = (B, Hkv, n_t)
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _decode_kernel, scale=1.0 / math.sqrt(Dh), window=window, n_t=n_t)

    out, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, it: (b,)),
            pl.BlockSpec((1, bt), lambda b, h, it: (b, it)),
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, it: (b, h, 0, 0)),
            pl.BlockSpec((1, bt, 1, Dh), lambda b, h, it: (b, it, h, 0)),
            pl.BlockSpec((1, bt, 1, Dh), lambda b, h, it: (b, it, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, it: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, it: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions.reshape(B), kv_positions, qg, k, v)
    return (out.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))
