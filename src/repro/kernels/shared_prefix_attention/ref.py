"""Pure-jnp oracle for shared-prefix decode attention.

The oracle materializes what the optimized path avoids: it broadcasts the
shared prefix KV to every request and runs ordinary attention over the
concatenated [prefix, suffix] cache.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def shared_prefix_attention_ref(q, prefix_k, prefix_v, suffix_k, suffix_v, *,
                                q_positions, suffix_positions):
    """q: (B,H,Dh); prefix_k/v: (P,Hkv,Dh) SHARED; suffix_k/v: (B,T,Hkv,Dh).

    Prefix slots occupy absolute positions [0, P); suffix_positions (B,T)
    carry absolute positions (−1 invalid).
    """
    B = q.shape[0]
    P = prefix_k.shape[0]
    pk = jnp.broadcast_to(prefix_k[None], (B,) + prefix_k.shape)
    pv = jnp.broadcast_to(prefix_v[None], (B,) + prefix_v.shape)
    k = jnp.concatenate([pk, suffix_k], axis=1)
    v = jnp.concatenate([pv, suffix_v], axis=1)
    prefix_pos = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (B, P))
    kv_pos = jnp.concatenate([prefix_pos, suffix_positions], axis=1)
    return decode_attention_ref(q, k, v, q_positions=q_positions,
                                kv_positions=kv_pos)
