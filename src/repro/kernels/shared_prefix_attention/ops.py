"""Public wrapper: shared-prefix pass + per-request suffix pass + LSE merge."""
from __future__ import annotations

import functools

import jax

from repro.kernels import env_interpret
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.shared_prefix_attention.kernel import prefix_attention_kernel



def _pick_block(s: int, target: int) -> int:
    if s % target == 0:
        return target
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=(
    "block_p", "block_t", "interpret"))
def _shared_prefix_attention_jit(q, prefix_k, prefix_v, suffix_k, suffix_v, *,
                                 q_positions, suffix_positions,
                                 block_p=1024, block_t=1024,
                                 interpret=False):
    B, H, Dh = q.shape
    P = prefix_k.shape[0]
    bp = _pick_block(P, block_p)
    bt = _pick_block(suffix_k.shape[1], block_t)

    prefix_positions = jnp.arange(P, dtype=jnp.int32)
    acc_p, m_p, l_p = prefix_attention_kernel(
        q, prefix_k, prefix_v, prefix_positions, block_p=bp,
        interpret=interpret)
    out_s, m_s, l_s = decode_attention_kernel(
        q, suffix_k, suffix_v, q_positions, suffix_positions,
        window=0, block_t=bt, interpret=interpret)

    # log-sum-exp merge of the two partials (prefix acc is unnormalized)
    out_p = acc_p / jnp.where(l_p == 0.0, 1.0, l_p)[..., None]
    m = jnp.maximum(m_p, m_s)
    w_p = jnp.exp(m_p - m) * l_p
    w_s = jnp.exp(m_s - m) * l_s
    den = jnp.where(w_p + w_s == 0.0, 1.0, w_p + w_s)
    out = (out_p.astype(jnp.float32) * w_p[..., None]
           + out_s.astype(jnp.float32) * w_s[..., None]) / den[..., None]
    return out.astype(q.dtype)


def shared_prefix_attention(q, prefix_k, prefix_v, suffix_k, suffix_v, *,
                            q_positions, suffix_positions,
                            block_p=1024, block_t=1024, interpret=False):
    """q: (B,H,Dh); prefix_k/v: (P,Hkv,Dh) ONE shared copy; suffix per-request.

    Prefix slots are absolute positions [0, P); all are visible to every
    decode query (the prefix is strictly in the past).  ``interpret`` is
    resolved against REPRO_PALLAS_INTERPRET before the jit boundary so
    the env override is part of the jit cache key.
    """
    return _shared_prefix_attention_jit(
        q, prefix_k, prefix_v, suffix_k, suffix_v,
        q_positions=q_positions, suffix_positions=suffix_positions,
        block_p=block_p, block_t=block_t, interpret=env_interpret(interpret))
