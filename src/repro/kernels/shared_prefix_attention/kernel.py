"""Shared-prefix (Hydragen-style) attention — Pallas TPU kernel.

Halo batches requests that share a workflow-template prompt; their KV
caches share a prefix.  Naive decode re-reads that prefix KV once PER
REQUEST (B× the HBM traffic) and multiplies it against G-row query tiles
(starving the 128×128 MXU).  This kernel restructures the computation:

  grid (Hkv, n_p) over the ONE shared prefix copy; each step loads a
  (bp, Dh) KV tile once and multiplies it against the queries of ALL B
  requests × G group heads at once — a (B·G, Dh) × (Dh, bp) matmul.

HBM traffic for the prefix drops B×; matmul rows grow from G to B·G
(e.g. 8 → 1024 at decode_32k), which is what keeps the MXU fed.  The
per-request suffix is handled by the ordinary decode kernel and the two
partial results are merged with the log-sum-exp combine — exactly the
flash-decoding merge, reused across the prefix/suffix split.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, finalize_online_softmax,
                                  online_softmax_update, qk_logits)


def _prefix_kernel(kp_ref, q_ref, k_ref, v_ref,
                   o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, n_p: int):
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[:, 0, :].astype(jnp.float32)              # (B*G, Dh)
    k = k_ref[:, 0, :].astype(jnp.float32)              # (bp, Dh)
    v = v_ref[:, 0, :].astype(jnp.float32)
    kp = kp_ref[...]                                    # (bp,)

    logits = qk_logits(q, k, scale)                     # (B*G, bp)
    mask = (kp >= 0)[None, :]

    acc_ref[...], m_ref[:, 0], l_ref[:, 0] = online_softmax_update(
        logits, mask, v, acc_ref[...], m_ref[:, 0], l_ref[:, 0])

    @pl.when(ip == n_p - 1)
    def _done():
        # unnormalized partial: the LSE combine divides once at the end
        out, m, l = finalize_online_softmax(
            acc_ref[...], m_ref[:, 0], l_ref[:, 0], normalize=False)
        o_ref[:, 0, :] = out.astype(o_ref.dtype)
        m_out_ref[:, 0] = m
        l_out_ref[:, 0] = l


# vmem-budget: 1.5 MiB @ block_p=1024 P=32768 B=8 H=32 Hkv=8 Dh=128
def prefix_attention_kernel(q, prefix_k, prefix_v, prefix_positions, *,
                            block_p: int, interpret: bool = False):
    """q: (B,H,Dh); prefix_k/v: (P,Hkv,Dh) shared across the batch.

    Returns UNNORMALIZED (acc (B,H,Dh) f32, m (B,H), l (B,H)).
    """
    B, H, Dh = q.shape
    P, Hkv = prefix_k.shape[0], prefix_k.shape[1]
    G = H // Hkv
    bp = min(block_p, P)
    assert P % bp == 0
    n_p = P // bp

    # fold batch into matmul rows, grouped per KV head:  (Hkv, B*G, Dh)
    qf = q.reshape(B, Hkv, G, Dh).transpose(1, 0, 2, 3).reshape(Hkv, B * G, Dh)

    kernel = functools.partial(
        _prefix_kernel, scale=1.0 / math.sqrt(Dh), n_p=n_p)

    acc, m, l = pl.pallas_call(
        kernel,
        grid=(Hkv, n_p),
        in_specs=[
            pl.BlockSpec((bp,), lambda h, ip: (ip,)),
            pl.BlockSpec((B * G, 1, Dh), lambda h, ip: (0, h, 0)),
            pl.BlockSpec((bp, 1, Dh), lambda h, ip: (ip, h, 0)),
            pl.BlockSpec((bp, 1, Dh), lambda h, ip: (ip, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((B * G, 1, Dh), lambda h, ip: (0, h, 0)),
            pl.BlockSpec((B * G, 1), lambda h, ip: (0, h)),
            pl.BlockSpec((B * G, 1), lambda h, ip: (0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * G, Hkv, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B * G, Hkv), jnp.float32),
            jax.ShapeDtypeStruct((B * G, Hkv), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B * G, Dh), jnp.float32),
            pltpu.VMEM((B * G, 1), jnp.float32),
            pltpu.VMEM((B * G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(prefix_positions, qf.swapaxes(0, 1), prefix_k, prefix_v)

    # (B*G, Hkv, ...) -> (B, H, ...)
    acc = acc.reshape(B, G, Hkv, Dh).transpose(0, 2, 1, 3).reshape(B, H, Dh)
    m = m.reshape(B, G, Hkv).transpose(0, 2, 1).reshape(B, H)
    l = l.reshape(B, G, Hkv).transpose(0, 2, 1).reshape(B, H)
    return acc, m, l
