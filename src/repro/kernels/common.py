"""Shared online-softmax building blocks for the decode-side kernels.

``decode_attention``, ``paged_decode_attention`` and
``shared_prefix_attention`` all run the same flash-decode recurrence:
f32 accumulation, a running row max ``m`` and normalizer ``l``, and the
``alpha = exp(m_prev - m_new)`` rescale when a new chunk raises the max.
The recurrence lives here once so a fix (e.g. the masked-row ``(m, l)``
pin below) lands in every kernel at the same time.

Masked-row semantics: a row whose every KV position is masked ends the
grid with ``l == 0``.  Its ``m`` is whatever ``NEG_INF`` arithmetic left
behind — finite garbage, not a value downstream LSE combines may ingest.
``finalize_online_softmax`` pins such rows to ``m = NEG_INF, l = 0`` and
emits a zero output row, which makes ``lse_combine`` treat them as an
empty partial (weight ``exp(NEG_INF - m_other) == 0``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Finite stand-in for -inf: exp(NEG_INF - NEG_INF) stays defined (== 1)
# inside the rescale, unlike a true -inf which would produce NaN.
NEG_INF = -1e30


def qk_logits(q, k, scale: float):
    """Scaled q @ k^T in f32: q (R, Dh), k (C, Dh) -> logits (R, C)."""
    return jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def online_softmax_update(logits, mask, v, acc, m_prev, l_prev):
    """One flash-decode chunk update in f32.

    logits (R, C) raw scores; mask (1|R, C) bool, False = excluded;
    v (C, Dh); acc (R, Dh), m_prev/l_prev (R,) the running state.
    Returns the updated ``(acc, m, l)``.
    """
    logits = jnp.where(mask, logits, NEG_INF)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = alpha * l_prev + p.sum(axis=-1)
    acc_new = acc * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return acc_new, m_new, l_new


def finalize_online_softmax(acc, m, l, *, normalize: bool = True):
    """End-of-grid epilogue: divide by ``l`` and pin fully-masked rows.

    Rows that saw no unmasked KV (``l == 0``) get ``out = 0`` and
    ``m = NEG_INF`` exactly, so LSE combines downstream see a proper
    empty partial instead of residue of NEG_INF arithmetic.  With
    ``normalize=False`` the accumulator is returned unnormalized (the
    shared-prefix partial contract); the ``(m, l)`` pin still applies.
    Returns ``(out_f32, m, l)``.
    """
    empty = l == 0.0
    if normalize:
        out = acc / jnp.where(empty, 1.0, l)[:, None]
    else:
        out = acc
    out = jnp.where(empty[:, None], 0.0, out)
    m = jnp.where(empty, NEG_INF, m)
    return out, m, l
