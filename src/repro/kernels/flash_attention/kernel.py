"""Flash prefill attention — Pallas TPU kernel.

Grid (B, H, n_q, n_kv); the kv dim is innermost so the online-softmax
running state (acc/m/l) lives in VMEM scratch across kv steps.

VMEM working set per step (bq=512, bk=512, Dh=128, bf16 in / f32 acc):
  q tile 512·128·2 = 128 KiB, k/v tiles 2·128 KiB, acc 512·128·4 = 256 KiB,
  logits 512·512·4 = 1 MiB  →  ~1.8 MiB, comfortably inside ~16 MiB VMEM.
MXU alignment: all matmul dims (bq, bk, Dh) are multiples of 128 at
production shapes; q rows fold the GQA group so the (bq, Dh)×(Dh, bk)
products keep the systolic array full.

Positions are explicit inputs (−1 = invalid slot), so causal masks,
sliding windows and ring-buffer caches all reduce to the same predicate —
no separate mask tensors in HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, n_kv: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)            # (bq, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    qp = qp_ref[0, :]                                    # (bq,) int32
    kp = kp_ref[0, :]                                    # (bk,)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (bq, bk)

    mask = (kp >= 0)[None, :]
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window > 0:
        mask = mask & (kp[None, :] > qp[:, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[:, 0]                                 # (bq,)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)                      # (bq,)
    p = jnp.exp(logits - m_new[:, None])                 # (bq, bk)
    l_new = alpha * l_ref[:, 0] + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new
    l_ref[:, 0] = l_new

    @pl.when(ik == n_kv - 1)
    def _done():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows
        o_ref[0, :, 0, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


# vmem-budget: 2.0 MiB @ block_q=512 block_kv=512 Sq=4096 Skv=4096 Dh=128
def flash_attention_kernel(q, k, v, q_positions, kv_positions, *,
                           causal: bool, window: int,
                           block_q: int, block_kv: int,
                           interpret: bool = False):
    """q: (B,Sq,H,Dh); k,v: (B,Skv,Hkv,Dh). Requires Sq%bq==0, Skv%bk==0."""
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq, bk = min(block_q, Sq), min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_q, n_kv = Sq // bq, Skv // bk
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(Dh), causal=causal,
        window=window, n_kv=n_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, bq, 1, Dh), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, iq, ik: (b, ik, h // G, 0)),
            pl.BlockSpec((1, bk, 1, Dh), lambda b, h, iq, ik: (b, ik, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dh), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
