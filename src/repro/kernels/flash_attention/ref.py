"""Pure-jnp oracle for flash prefill attention (GQA, causal, window)."""
from __future__ import annotations

import math

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, q_positions, kv_positions,
                        causal=True, window=0):
    """q: (B,Sq,H,Dh); k,v: (B,Skv,Hkv,Dh); positions int32, -1 invalid."""
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(Dh)
    qp = q_positions[:, None, None, :, None]
    kp = kv_positions[:, None, None, None, :]
    mask = kp >= 0
    if causal:
        mask = mask & (kp <= qp)
    if window:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dh)
