"""Public jit'd wrapper for the flash prefill attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import env_interpret

from repro.kernels.flash_attention.kernel import flash_attention_kernel



def _pick_block(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (prefers target itself)."""
    if s % target == 0:
        return target
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def _flash_attention_jit(q, k, v, *, q_positions, kv_positions, causal=True,
                         window=0, block_q=512, block_kv=512,
                         interpret=False):
    bq = _pick_block(q.shape[1], block_q)
    bk = _pick_block(k.shape[1], block_kv)
    return flash_attention_kernel(
        q, k, v, q_positions, kv_positions, causal=causal, window=window,
        block_q=bq, block_kv=bk, interpret=interpret)


def flash_attention(q, k, v, *, q_positions, kv_positions, causal=True,
                    window=0, block_q=512, block_kv=512, interpret=False):
    """``interpret`` is resolved against REPRO_PALLAS_INTERPRET before
    the jit boundary so the env override is part of the jit cache key."""
    return _flash_attention_jit(
        q, k, v, q_positions=q_positions, kv_positions=kv_positions,
        causal=causal, window=window, block_q=block_q, block_kv=block_kv,
        interpret=env_interpret(interpret))
