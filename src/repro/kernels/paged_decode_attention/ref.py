"""Pure-jnp oracle for paged GQA flash-decode: gather the pages dense,
then run the contiguous decode-attention reference over them."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths,
                               return_lse: bool = False):
    """q: (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh);
    page_table: (B, n_pages) int32; lengths: (B,) int32 (-1 = padding).

    Token position of page slot (i, j) in a row is ``i*page + j``; valid
    while ``<= lengths[b]`` (the newest token's KV is already in its
    page).  Returns out (B,H,Dh); with return_lse also (m, l).
    """
    B, n_pages = page_table.shape
    _, page_size, Hkv, Dh = k_pages.shape
    T = n_pages * page_size
    k = k_pages[page_table].reshape(B, T, Hkv, Dh)
    v = v_pages[page_table].reshape(B, T, Hkv, Dh)
    kv_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return decode_attention_ref(
        q, k, v, q_positions=lengths, kv_positions=kv_positions,
        return_lse=return_lse)


def scatter_append_ref(k_pages, v_pages, page_table, lengths, k_new, v_new):
    """The scatter the fused kernel absorbs, as a pure-jnp oracle.

    k_new/v_new: (B, Hkv, Dh) — written to ``page_table[b, len // page]``
    at offset ``len % page`` for rows with ``lengths[b] >= 0``; padding
    rows write nothing (out-of-bounds scatter, dropped).  Returns the
    updated ``(k_pages, v_pages)``.
    """
    P, page_size = k_pages.shape[0], k_pages.shape[1]
    valid = lengths >= 0
    posc = jnp.maximum(lengths, 0)
    wp = jnp.take_along_axis(page_table, (posc // page_size)[:, None],
                             axis=1)[:, 0]
    wp = jnp.where(valid, wp, P)                         # OOB -> dropped
    wo = posc % page_size
    k_pages = k_pages.at[wp, wo].set(k_new, mode="drop")
    v_pages = v_pages.at[wp, wo].set(v_new, mode="drop")
    return k_pages, v_pages


def fused_paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                     lengths, k_new, v_new,
                                     return_lse: bool = False):
    """Scatter-then-attend oracle for the fused append+attend kernel:
    the fused variant must equal appending first (scatter_append_ref)
    and attending after, exactly.  Returns ``(out, k_pages, v_pages)``
    (plus ``m, l`` between out and the pools with ``return_lse``)."""
    k_pages, v_pages = scatter_append_ref(
        k_pages, v_pages, page_table, lengths, k_new, v_new)
    res = paged_decode_attention_ref(
        q, k_pages, v_pages, page_table, lengths, return_lse=return_lse)
    if return_lse:
        out, m, l = res
        return out, m, l, k_pages, v_pages
    return res, k_pages, v_pages
