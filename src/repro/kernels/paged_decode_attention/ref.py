"""Pure-jnp oracle for paged GQA flash-decode: gather the pages dense,
then run the contiguous decode-attention reference over them."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths,
                               return_lse: bool = False):
    """q: (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh);
    page_table: (B, n_pages) int32; lengths: (B,) int32 (-1 = padding).

    Token position of page slot (i, j) in a row is ``i*page + j``; valid
    while ``<= lengths[b]`` (the newest token's KV is already in its
    page).  Returns out (B,H,Dh); with return_lse also (m, l).
    """
    B, n_pages = page_table.shape
    _, page_size, Hkv, Dh = k_pages.shape
    T = n_pages * page_size
    k = k_pages[page_table].reshape(B, T, Hkv, Dh)
    v = v_pages[page_table].reshape(B, T, Hkv, Dh)
    kv_positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return decode_attention_ref(
        q, k, v, q_positions=lengths, kv_positions=kv_positions,
        return_lse=return_lse)
