"""Public jit'd wrappers for paged GQA flash-decode.

Variant selection is autotuned: ``benchmarks/kernel_bench.py`` sweeps
``(pages_per_block, grid_layout, fused on/off)`` per pool shape, scores
achieved HBM bandwidth against the ``launch/roofline.py`` peaks, and
persists the winners into the checked-in ``autotune.json`` next to this
module.  At call time the table is consulted by pool-shape key (see
:func:`kernel_config`); environment overrides:

* ``REPRO_KERNEL_AUTOTUNE=<path>`` — load an alternate winner table
  (e.g. a freshly swept one, before checking it in).
* ``REPRO_PAGED_VARIANT=single|blocked|fused`` — force the kernel
  variant regardless of the table (the A/B harness uses this hook).
"""
from __future__ import annotations

import functools
import json
import os
from typing import Optional

import jax

from repro.kernels import env_interpret
from repro.kernels.paged_decode_attention.kernel import (
    GRID_LAYOUTS, fused_paged_decode_attention_kernel,
    paged_decode_attention_blocked_kernel, paged_decode_attention_kernel)

VARIANTS = ("single", "blocked", "fused")
_TABLE_ENV = "REPRO_KERNEL_AUTOTUNE"
_VARIANT_ENV = "REPRO_PAGED_VARIANT"
_DEFAULT_TABLE = os.path.join(os.path.dirname(__file__), "autotune.json")


def shape_key(page_size: int, n_kv_heads: int, head_dim: int,
              group: int) -> str:
    """Autotune-table key for a pool/query shape."""
    return f"ps{page_size}-hkv{n_kv_heads}-dh{head_dim}-g{group}"


@functools.lru_cache(maxsize=None)
def _load_table(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def kernel_config(page_size: int, n_kv_heads: int, head_dim: int,
                  group: int) -> dict:
    """Resolve the autotuned ``{variant, pages_per_block, grid_layout}``
    for a shape — exact key first, then the table's ``default`` entry,
    then the built-in fallback."""
    path = os.environ.get(_TABLE_ENV, _DEFAULT_TABLE)
    table = _load_table(path).get("configs", {})
    cfg = dict(table.get("default",
                         {"variant": "fused", "pages_per_block": 4,
                          "grid_layout": "bh"}))
    cfg.update(table.get(shape_key(page_size, n_kv_heads, head_dim, group),
                         {}))
    forced = os.environ.get(_VARIANT_ENV, "")
    if forced:
        assert forced in VARIANTS, f"{_VARIANT_ENV}={forced!r} not in {VARIANTS}"
        cfg["variant"] = forced
    assert cfg["variant"] in VARIANTS
    assert cfg["grid_layout"] in GRID_LAYOUTS
    cfg["pages_per_block"] = max(1, int(cfg["pages_per_block"]))
    return cfg


def _resolve(q, k_pages, variant, pages_per_block, grid_layout):
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    H, Dh = q.shape[-2], q.shape[-1]
    cfg = kernel_config(page_size, Hkv, Dh, H // Hkv)
    return (variant or cfg["variant"],
            pages_per_block or cfg["pages_per_block"],
            grid_layout or cfg["grid_layout"])


@functools.partial(jax.jit, static_argnames=(
    "return_lse", "interpret", "variant", "pages_per_block", "grid_layout"))
def _paged_decode_attention_jit(q, k_pages, v_pages, page_table, lengths, *,
                                return_lse=False, interpret=False,
                                variant="single", pages_per_block=1,
                                grid_layout="bh"):
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1
        q = q[:, 0]
    if variant == "single":
        out, m, l = paged_decode_attention_kernel(
            q, k_pages, v_pages, page_table, lengths, interpret=interpret)
    else:
        out, m, l = paged_decode_attention_blocked_kernel(
            q, k_pages, v_pages, page_table, lengths,
            pages_per_block=pages_per_block, grid_layout=grid_layout,
            interpret=interpret)
    if squeeze:
        out = out[:, None]
    if return_lse:
        return out, m, l
    return out


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           return_lse=False, interpret=False,
                           variant: Optional[str] = None,
                           pages_per_block: Optional[int] = None,
                           grid_layout: Optional[str] = None):
    """q: (B,1,H,Dh) or (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh);
    page_table (B, n_pages) int32; lengths (B,) int32 (-1 = padded row).
    Returns attention output at q's rank (plus lse when asked).

    ``variant``/``pages_per_block``/``grid_layout`` default to the
    autotune table (module docstring); ``variant="fused"`` resolves to
    ``blocked`` here — the append-fusing entry point is
    :func:`fused_paged_decode_attention`, which needs the new KV rows.

    ``interpret`` is resolved against REPRO_PALLAS_INTERPRET before the
    jit boundary so the env override is part of the jit cache key.
    """
    variant, ppb, layout = _resolve(q, k_pages, variant, pages_per_block,
                                    grid_layout)
    if variant == "fused":
        variant = "blocked"
    return _paged_decode_attention_jit(
        q, k_pages, v_pages, page_table, lengths, return_lse=return_lse,
        interpret=env_interpret(interpret), variant=variant,
        pages_per_block=ppb, grid_layout=layout)


@functools.partial(jax.jit, static_argnames=(
    "return_lse", "interpret", "pages_per_block", "grid_layout"))
def _fused_paged_decode_attention_jit(q, k_pages, v_pages, page_table,
                                      lengths, k_new, v_new, *,
                                      return_lse=False, interpret=False,
                                      pages_per_block=2, grid_layout="bh"):
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1
        q = q[:, 0]
    out, m, l, k_out, v_out = fused_paged_decode_attention_kernel(
        q, k_pages, v_pages, page_table, lengths, k_new, v_new,
        pages_per_block=pages_per_block, grid_layout=grid_layout,
        interpret=interpret)
    if squeeze:
        out = out[:, None]
    if return_lse:
        return out, m, l, k_out, v_out
    return out, k_out, v_out


def fused_paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                                 k_new, v_new, *, return_lse=False,
                                 interpret=False,
                                 pages_per_block: Optional[int] = None,
                                 grid_layout: Optional[str] = None):
    """Append-then-attend in one kernel dispatch.

    k_new/v_new: (B, Hkv, Dh), the newest token's KV rows (pool dtype);
    written at ``page_table[b, lengths[b] // page] . (lengths[b] %
    page)`` for rows with ``lengths[b] >= 0`` — that page must be
    private to the row (``PagedKVCache.prepare_append`` COW contract).
    The input pools are aliased in place; callers must adopt the
    RETURNED pool arrays and drop their references to the inputs.

    Returns ``(out, k_pages, v_pages)``; with ``return_lse``,
    ``(out, m, l, k_pages, v_pages)``.
    """
    _, ppb, layout = _resolve(q, k_pages, None, pages_per_block, grid_layout)
    return _fused_paged_decode_attention_jit(
        q, k_pages, v_pages, page_table, lengths, k_new, v_new,
        return_lse=return_lse, interpret=env_interpret(interpret),
        pages_per_block=ppb, grid_layout=layout)
