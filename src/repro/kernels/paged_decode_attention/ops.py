"""Public jit'd wrapper for paged GQA flash-decode."""
from __future__ import annotations

import functools

import jax

from repro.kernels import env_interpret
from repro.kernels.paged_decode_attention.kernel import \
    paged_decode_attention_kernel


@functools.partial(jax.jit, static_argnames=("return_lse", "interpret"))
def _paged_decode_attention_jit(q, k_pages, v_pages, page_table, lengths, *,
                                return_lse=False, interpret=False):
    squeeze = q.ndim == 4
    if squeeze:
        assert q.shape[1] == 1
        q = q[:, 0]
    out, m, l = paged_decode_attention_kernel(
        q, k_pages, v_pages, page_table, lengths, interpret=interpret)
    if squeeze:
        out = out[:, None]
    if return_lse:
        return out, m, l
    return out


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           return_lse=False, interpret=False):
    """q: (B,1,H,Dh) or (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh);
    page_table (B, n_pages) int32; lengths (B,) int32 (-1 = padded row).
    Returns attention output at q's rank (plus lse when asked).

    ``interpret`` is resolved against REPRO_PALLAS_INTERPRET before the
    jit boundary so the env override is part of the jit cache key.
    """
    return _paged_decode_attention_jit(
        q, k_pages, v_pages, page_table, lengths, return_lse=return_lse,
        interpret=env_interpret(interpret))
