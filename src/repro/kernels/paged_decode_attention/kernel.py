"""Paged GQA flash-decode — Pallas TPU kernel over non-contiguous pages.

The decode-attention kernel streams a *contiguous* per-sequence KV block;
this one attends directly over the engine's device-resident page pool,
so the dense gather that used to materialize each sequence (the host
``_rebuild_view`` round-trip) never happens.  Per grid step one physical
page is DMA'd into VMEM — its index comes from the scalar-prefetched
page table (``pltpu.PrefetchScalarGridSpec``), which is how TPUs chase
PagedAttention's pointers with dense DMA.

Grid: ``(B, Hkv, n_pages)``, page dim innermost; the online-softmax
inner loop is the flash-decode recurrence from
``kernels/decode_attention`` with the KV-chunk replaced by a page.
Positions are implicit: page ``i`` of a row's table holds tokens
``[i*page_size, (i+1)*page_size)`` of that sequence, valid while
``<= lengths[b]`` (the newest token's KV is scattered into its page
*before* the kernel runs, so ``lengths[b]`` is the query position).
Rows with ``lengths[b] < 0`` are padding: fully masked, output zeros.

The optional (m, l) outputs expose the log-sum-exp state for combining
with other passes (e.g. a shared-prefix split), mirroring
``decode_attention``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, m_out_ref, l_out_ref,
                         acc_ref, m_ref, l_ref, *,
                         scale: float, page_size: int, n_pages: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    length = len_ref[b]                                  # query position

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale      # (G, page)

    # token t of page slot j is position it*page_size + j in the
    # sequence; stale / unwritten slots sit past `length` and padding
    # rows carry length < 0 (everything masked)
    kv_pos = it * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    mask = kv_pos <= length
    logits = jnp.where(mask[None, :], logits, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    p = jnp.where(mask[None, :], p, 0.0)
    l_ref[:, 0] = alpha * l_ref[:, 0] + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, 0] = m_new

    @pl.when(it == n_pages - 1)
    def _done():
        l = l_ref[:, 0]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)
        m_out_ref[0, 0, :, 0] = m_ref[:, 0]
        l_out_ref[0, 0, :, 0] = l


# vmem-budget: 0.25 MiB @ page_size=64 Dh=128 H=32 Hkv=8
def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, lengths,
                                  *, interpret: bool = False):
    """q: (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh) — the pool;
    page_table: (B, n_pages) int32; lengths: (B,) int32 (-1 = padding).

    Returns (out (B,H,Dh), m (B,H), l (B,H)).
    """
    B, H, Dh = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    n_pages = page_table.shape[1]
    G = H // Hkv
    grid = (B, Hkv, n_pages)
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _paged_decode_kernel, scale=1.0 / math.sqrt(Dh),
        page_size=page_size, n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # page_table, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, i, pt, ln: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return (out.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))
