"""Paged GQA flash-decode — Pallas TPU kernels over non-contiguous pages.

The decode-attention kernel streams a *contiguous* per-sequence KV block;
these kernels attend directly over the engine's device-resident page
pool, so the dense gather that used to materialize each sequence (the
host ``_rebuild_view`` round-trip) never happens.  Three variants share
the flash-decode recurrence from ``kernels/common``:

* ``single``  — one physical page per grid step, fetched by BlockSpec
  indexing through the scalar-prefetched page table
  (``pltpu.PrefetchScalarGridSpec``).  DMA and compute serialize: the
  pipeline stalls on every page fetch.  Kept as the A/B baseline.
* ``blocked`` — the innermost grid dim covers ``pages_per_block >= 2``
  physical pages per step.  The pool stays in ANY/HBM and each block is
  hand-DMA'd into a 2-slot VMEM scratch ring, double-buffered: block
  ``i+1``'s DMA is issued before block ``i``'s compute, so page fetches
  overlap the matmuls.  Per-row early-out: a page whose positions start
  past ``lengths[b]`` is neither copied nor multiplied, so short rows
  stop paying for the longest row's page count.
* ``fused``   — ``blocked`` plus the scatter-append folded in: the
  newest token's KV rows (one ``(Hkv, Dh)`` row per sequence) are
  DMA'd into their ``(page, offset)`` pool slots INSIDE the same
  ``pallas_call``, before any page of that row is read.  This removes
  the separate scatter dispatch in ``TransformerLM.paged_decode_step``
  and one full pool round-trip per layer per step.  The pool operands
  are aliased to outputs (``input_output_aliases``) so the append is
  in-place.

Grid: ``(B, Hkv, n)`` (layout ``bh``) or ``(Hkv, B, n)`` (layout
``hb``), block/page dim innermost — TPU grids run sequentially with the
last dim minor, which is what makes the fused write-before-read ordering
sound.  Positions are implicit: page ``i`` of a row's table holds tokens
``[i*page_size, (i+1)*page_size)``, valid while ``<= lengths[b]``
(``lengths[b]`` is the query position).  Rows with ``lengths[b] < 0``
are padding: fully masked, output zeros, and — fused — nothing written.

Fused-append contract (DESIGN.md §3): the write target is derived
in-kernel from the prefetched scalars — ``page_table[b, len // page]``
at offset ``len % page`` — and that page must be PRIVATE to row ``b``
(refcount 1).  ``PagedKVCache.prepare_append`` guarantees this: a row
at a page boundary gets a fresh page, a row appending into a shared
page gets a copy-on-write clone first.  Aliased *read* pages (shared
prefixes) remain fine — only the append page must be exclusive.

The optional (m, l) outputs expose the log-sum-exp state for combining
with other passes (e.g. a shared-prefix split), mirroring
``decode_attention``; fully-masked rows are pinned to
``(NEG_INF, 0)`` by ``finalize_online_softmax``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, finalize_online_softmax,
                                  online_softmax_update, qk_logits)

GRID_LAYOUTS = ("bh", "hb")


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref,
                         o_ref, m_out_ref, l_out_ref,
                         acc_ref, m_ref, l_ref, *,
                         scale: float, page_size: int, n_pages: int):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # (G, Dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)            # (page, Dh)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    length = len_ref[b]                                  # query position

    logits = qk_logits(q, k, scale)                      # (G, page)

    # token t of page slot j is position it*page_size + j in the
    # sequence; stale / unwritten slots sit past `length` and padding
    # rows carry length < 0 (everything masked)
    kv_pos = it * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)[0]
    mask = kv_pos <= length

    acc_ref[...], m_ref[:, 0], l_ref[:, 0] = online_softmax_update(
        logits, mask[None, :], v, acc_ref[...], m_ref[:, 0], l_ref[:, 0])

    @pl.when(it == n_pages - 1)
    def _done():
        out, m, l = finalize_online_softmax(
            acc_ref[...], m_ref[:, 0], l_ref[:, 0])
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)
        m_out_ref[0, 0, :, 0] = m
        l_out_ref[0, 0, :, 0] = l


# vmem-budget: 0.25 MiB @ page_size=64 Dh=128 H=32 Hkv=8
def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, lengths,
                                  *, interpret: bool = False):
    """q: (B,H,Dh); k_pages/v_pages: (P, page, Hkv, Dh) — the pool;
    page_table: (B, n_pages) int32; lengths: (B,) int32 (-1 = padding).

    Returns (out (B,H,Dh), m (B,H), l (B,H)).
    """
    B, H, Dh = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    n_pages = page_table.shape[1]
    G = H // Hkv
    grid = (B, Hkv, n_pages)
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _paged_decode_kernel, scale=1.0 / math.sqrt(Dh),
        page_size=page_size, n_pages=n_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # page_table, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, Dh),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, i, pt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, i, pt, ln: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return (out.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


def _paged_blocked_kernel(pt_ref, len_ref, *refs, scale: float,
                          page_size: int, ppb: int, nb: int,
                          layout: str, fused: bool):
    """Shared body of the ``blocked`` and ``fused`` variants.

    Positional refs after the two scalar-prefetch refs:
      blocked: q, k_hbm, v_hbm | o, m_out, l_out
               | acc, m, l, k_buf, v_buf, sems
      fused:   q, k_hbm, v_hbm, k_new, v_new | o, m_out, l_out, k_out,
               v_out | acc, m, l, k_buf, v_buf, sems, wsem
    With fused the pool inputs are aliased to (k_out, v_out); all pool
    traffic goes through the OUTPUT refs so the in-kernel append and the
    block reads see one coherent buffer.
    """
    if fused:
        (q_ref, _k_in, _v_in, knew_ref, vnew_ref,
         o_ref, m_out_ref, l_out_ref, k_hbm, v_hbm,
         acc_ref, m_ref, l_ref, k_buf, v_buf, sems, wsem) = refs
    else:
        (q_ref, k_hbm, v_hbm,
         o_ref, m_out_ref, l_out_ref,
         acc_ref, m_ref, l_ref, k_buf, v_buf, sems) = refs

    if layout == "bh":
        b, h, it = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    else:                                    # "hb": Hkv outermost
        h, b, it = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    length = len_ref[b]                                  # query position
    # pages holding positions <= length; 0 for padding rows -> the row
    # issues no DMA and no compute (the per-row early-out)
    np_b = jnp.where(length < 0, 0, length // page_size + 1)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if fused:
        # Append the newest token's KV before ANY page of row b is read.
        # First visit of row b is (hkv 0, block 0) under both layouts;
        # the wait() before the warm-up reads below gives write->read
        # ordering on the sequential TPU grid.  Padding rows write
        # nothing (DMA has no out-of-bounds drop mode, so gate, never
        # clamp).  Target page is private to row b by the
        # prepare_append COW contract (module docstring).
        @pl.when((h == 0) & (it == 0) & (length >= 0))
        def _append_new():
            wp = pt_ref[b, length // page_size]
            wo = length % page_size
            ck = pltpu.make_async_copy(
                knew_ref.at[pl.ds(b, 1)], k_hbm.at[wp, pl.ds(wo, 1)],
                wsem.at[0])
            cv = pltpu.make_async_copy(
                vnew_ref.at[pl.ds(b, 1)], v_hbm.at[wp, pl.ds(wo, 1)],
                wsem.at[1])
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()

    def block_dma(j, slot, start: bool):
        # start/wait the per-page copies of block j into ring slot
        # `slot`; both gate on the SAME per-page live predicate (np_b
        # depends only on b, constant along the block dim), so every
        # started DMA is waited exactly once.
        for jj in range(ppb):
            idx = j * ppb + jj
            page = pt_ref[b, idx]

            @pl.when(idx < np_b)
            def _():
                ck = pltpu.make_async_copy(
                    k_hbm.at[page, :, h], k_buf.at[slot, jj],
                    sems.at[slot, jj, 0])
                cv = pltpu.make_async_copy(
                    v_hbm.at[page, :, h], v_buf.at[slot, jj],
                    sems.at[slot, jj, 1])
                if start:
                    ck.start()
                    cv.start()
                else:
                    ck.wait()
                    cv.wait()

    # double buffering: warm-up block 0, then issue block it+1 before
    # waiting on block it, so the next fetch overlaps this compute
    @pl.when(it == 0)
    def _warmup():
        block_dma(it, it % 2, start=True)

    @pl.when(it + 1 < nb)
    def _prefetch_next():
        block_dma(it + 1, (it + 1) % 2, start=True)

    block_dma(it, it % 2, start=False)

    q = q_ref[0, 0, :, :].astype(jnp.float32)            # (G, Dh)
    slot = it % 2
    for jj in range(ppb):
        # per-page compute, gated: dead pages hold stale VMEM garbage
        # (never DMA'd), so they must not reach the matmul
        @pl.when(it * ppb + jj < np_b)
        def _page_update():
            k = k_buf[slot, jj].astype(jnp.float32)      # (page, Dh)
            v = v_buf[slot, jj].astype(jnp.float32)
            logits = qk_logits(q, k, scale)              # (G, page)
            kv_pos = (it * ppb + jj) * page_size + jax.lax.broadcasted_iota(
                jnp.int32, (1, page_size), 1)[0]
            mask = kv_pos <= length
            acc_ref[...], m_ref[:, 0], l_ref[:, 0] = online_softmax_update(
                logits, mask[None, :], v,
                acc_ref[...], m_ref[:, 0], l_ref[:, 0])

    @pl.when(it == nb - 1)
    def _done():
        out, m, l = finalize_online_softmax(
            acc_ref[...], m_ref[:, 0], l_ref[:, 0])
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)
        m_out_ref[0, 0, :, 0] = m
        l_out_ref[0, 0, :, 0] = l


def _blocked_specs(B, Hkv, G, Dh, nb, layout):
    """Grid + q/out BlockSpecs for both grid layouts (block dim minor)."""
    if layout == "bh":
        grid = (B, Hkv, nb)

        def qmap(b, h, i, pt, ln):
            return (b, h, 0, 0)

        def smap(b, h, i, pt, ln):
            return (b, h, 0, 0)
    else:
        grid = (Hkv, B, nb)

        def qmap(h, b, i, pt, ln):
            return (b, h, 0, 0)

        def smap(h, b, i, pt, ln):
            return (b, h, 0, 0)
    q_spec = pl.BlockSpec((1, 1, G, Dh), qmap)
    o_spec = pl.BlockSpec((1, 1, G, Dh), smap)
    ml_spec = pl.BlockSpec((1, 1, G, 1), smap)
    return grid, q_spec, o_spec, ml_spec


def _pad_page_table(page_table, ppb):
    """Pad the page dim to a multiple of ppb; padded entries are never
    DMA'd (they sit past every row's np_b) so the pad value is inert."""
    n_pages = page_table.shape[1]
    pad = (-n_pages) % ppb
    if pad:
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)))
    return page_table, (n_pages + pad) // ppb


# vmem-budget: 0.6 MiB @ pages_per_block=4 page_size=64 Dh=128 H=32 Hkv=8
def paged_decode_attention_blocked_kernel(q, k_pages, v_pages, page_table,
                                          lengths, *, pages_per_block: int,
                                          grid_layout: str = "bh",
                                          interpret: bool = False):
    """Multi-page double-buffered variant.  Same contract as
    :func:`paged_decode_attention_kernel`; ``pages_per_block`` pages are
    hand-DMA'd per grid step (the table is padded up to a multiple — a
    row whose page count the block size does not divide simply has dead
    tail pages in its last block).
    """
    B, H, Dh = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    G = H // Hkv
    ppb = pages_per_block
    assert ppb >= 1
    assert grid_layout in GRID_LAYOUTS
    page_table, nb = _pad_page_table(page_table, ppb)
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _paged_blocked_kernel, scale=1.0 / math.sqrt(Dh),
        page_size=page_size, ppb=ppb, nb=nb, layout=grid_layout,
        fused=False)

    grid, q_spec, o_spec, ml_spec = _blocked_specs(B, Hkv, G, Dh, nb,
                                                   grid_layout)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # page_table, lengths
        grid=grid,
        in_specs=[
            q_spec,
            pl.BlockSpec(memory_space=pltpu.ANY),    # k pool stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),    # v pool stays in HBM
        ],
        out_specs=[o_spec, ml_spec, ml_spec],
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((2, ppb, page_size, Dh), k_pages.dtype),
            pltpu.VMEM((2, ppb, page_size, Dh), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2, ppb, 2)),
        ],
    )
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages)
    return (out.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H))


# vmem-budget: 0.6 MiB @ pages_per_block=4 page_size=64 Dh=128 H=32 Hkv=8
def fused_paged_decode_attention_kernel(q, k_pages, v_pages, page_table,
                                        lengths, k_new, v_new, *,
                                        pages_per_block: int,
                                        grid_layout: str = "bh",
                                        interpret: bool = False):
    """Blocked variant with the scatter-append fused in.

    k_new/v_new: (B, Hkv, Dh) — the newest token's KV rows, written to
    ``page_table[b, lengths[b] // page] . (lengths[b] % page)`` inside
    the kernel (nothing written for padding rows).  The pool arrays are
    aliased in-place; callers must treat the INPUT pool buffers as
    consumed (the jit wrapper in ops.py donates them).

    Returns (out (B,H,Dh), m (B,H), l (B,H), k_pages, v_pages).
    """
    B, H, Dh = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    G = H // Hkv
    ppb = pages_per_block
    assert ppb >= 1
    assert grid_layout in GRID_LAYOUTS
    assert k_new.shape == (B, Hkv, Dh)
    assert k_new.dtype == k_pages.dtype and v_new.dtype == v_pages.dtype, \
        "fused append DMAs raw bytes: new-KV dtype must match the pool"
    page_table, nb = _pad_page_table(page_table, ppb)
    qg = q.reshape(B, Hkv, G, Dh)

    kernel = functools.partial(
        _paged_blocked_kernel, scale=1.0 / math.sqrt(Dh),
        page_size=page_size, ppb=ppb, nb=nb, layout=grid_layout,
        fused=True)

    grid, q_spec, o_spec, ml_spec = _blocked_specs(B, Hkv, G, Dh, nb,
                                                   grid_layout)
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,               # page_table, lengths
        grid=grid,
        in_specs=[q_spec, any_spec, any_spec, any_spec, any_spec],
        out_specs=[o_spec, ml_spec, ml_spec, any_spec, any_spec],
        scratch_shapes=[
            pltpu.VMEM((G, Dh), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((2, ppb, page_size, Dh), k_pages.dtype),
            pltpu.VMEM((2, ppb, page_size, Dh), v_pages.dtype),
            pltpu.SemaphoreType.DMA((2, ppb, 2)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    out, m, l, k_out, v_out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # operand indices COUNT the scalar-prefetch args: (pt, lens, q,
        # k_pages, v_pages, k_new, v_new) -> pools are 3 and 4
        input_output_aliases={3: 3, 4: 4},
        interpret=interpret,
    )(page_table, lengths, qg, k_pages, v_pages, k_new, v_new)
    return (out.reshape(B, H, Dh), m.reshape(B, H), l.reshape(B, H),
            k_out, v_out)
