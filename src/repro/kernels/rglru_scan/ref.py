"""Pure-jnp oracle for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def linear_scan_ref(a, b, h0=None):
    """a, b: (B,S,D) f32. Sequential scan oracle (from h0 or zeros)."""
    B, S, D = a.shape
    h = jnp.zeros((B, D), a.dtype) if h0 is None else h0

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    _, hs = lax.scan(step, h, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
