"""Public jit'd wrapper for the RG-LRU blocked scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels import env_interpret

from repro.kernels.rglru_scan.kernel import linear_scan_kernel



def _pick_block(s: int, target: int) -> int:
    if s % target == 0:
        return target
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=(
    "block_b", "block_s", "block_d", "interpret"))
def _linear_scan_jit(a, b, *, block_b=8, block_s=16, block_d=512,
                     interpret=False):
    bb = _pick_block(a.shape[0], block_b)
    bs = _pick_block(a.shape[1], block_s)
    bd = _pick_block(a.shape[2], block_d)
    return linear_scan_kernel(a, b, block_b=bb, block_s=bs, block_d=bd,
                              interpret=interpret)


def linear_scan(a, b, *, block_b=8, block_s=16, block_d=512, interpret=False):
    """``interpret`` is resolved against REPRO_PALLAS_INTERPRET before
    the jit boundary so the env override is part of the jit cache key."""
    return _linear_scan_jit(a, b, block_b=block_b, block_s=block_s,
                            block_d=block_d,
                            interpret=env_interpret(interpret))
