"""RG-LRU blocked linear-recurrence scan — Pallas TPU kernel.

h_t = a_t ⊙ h_{t-1} + b_t, elementwise over the channel dim.  The
recurrence is sequential in time but embarrassingly parallel over
(batch, channel), so the kernel tiles those dims across the grid and
walks sequence blocks in the innermost (sequential) grid dim, carrying
h in VMEM scratch.  Within a block the time loop is unrolled (``bs``
steps of (bb, bd) vector FMAs on the VPU).

Grid (n_batch, n_chan, n_seq); block (bb, bs, bd).  VMEM per step:
a/b tiles 2·bb·bs·bd·4 B + carry bb·bd·4 B — e.g. (8, 256, 512) f32
tiles = 8.4 MiB, inside VMEM.  Channel tiles of 512 keep lanes full
(multiples of 128); the unrolled time loop keeps the VPU pipelined
without materializing the (B,S,D) cumulative-product tensor that the
associative-scan XLA path needs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, o_ref, h_ref, *, bs: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    h = h_ref[...]                                   # (bb, bd) f32
    for t in range(bs):                              # static unroll
        h = a_ref[:, t, :] * h + b_ref[:, t, :]
        o_ref[:, t, :] = h
    h_ref[...] = h


# vmem-budget: 1.0 MiB @ block_b=8 block_s=16 block_d=512 B=8 S=4096 D=1024
def linear_scan_kernel(a, b, *, block_b: int, block_s: int, block_d: int,
                       interpret: bool = False):
    """a, b: (B,S,D) f32 -> h (B,S,D) f32 from zero initial state."""
    B, S, D = a.shape
    bb, bs, bd = min(block_b, B), min(block_s, S), min(block_d, D)
    assert B % bb == 0 and S % bs == 0 and D % bd == 0
    grid = (B // bb, D // bd, S // bs)               # seq dim innermost

    return pl.pallas_call(
        functools.partial(_scan_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bd), lambda ib, id_, it: (ib, it, id_)),
            pl.BlockSpec((bb, bs, bd), lambda ib, id_, it: (ib, it, id_)),
        ],
        out_specs=pl.BlockSpec((bb, bs, bd), lambda ib, id_, it: (ib, it, id_)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), a.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(a, b)
