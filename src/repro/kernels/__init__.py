"""Pallas TPU kernels for the compute hot-spots of the serving path.

Layout per kernel: ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd public wrapper), ``ref.py`` (pure-jnp oracle used by the
allclose sweeps in tests/).

Kernels are TPU-TARGETED and validated with ``interpret=True`` on CPU
(this container has no TPU).  The XLA reference path (same math) is what
the dry-run compiles; the kernel/XLA switch is ``cfg.attention_impl``.

* flash_attention      — causal/SWA prefill attention, online softmax
* decode_attention     — GQA flash-decode over a (ring-buffer) KV cache,
                         KV-chunk grid + log-sum-exp combine
* paged_decode_attention — flash-decode directly over the device-resident
                         page pool: a scalar-prefetched page table picks
                         each grid step's page, so non-contiguous
                         sequences decode in place (no dense gather)
* shared_prefix_attention — Hydragen-style: one pass over the SHARED prefix
                         KV for the whole batch (B·G-row matmuls feed the
                         MXU) + per-request suffix pass, LSE-combined.
                         This is the kernel-level realization of Halo's
                         KV-cache sharing.
* rglru_scan           — RG-LRU blocked linear-recurrence scan (Griffin)
"""

import os


def env_interpret(interpret: bool) -> bool:
    """Force Pallas interpret mode via REPRO_PALLAS_INTERPRET=1 (CI runs
    the kernel suite this way on CPU runners)."""
    return interpret or os.environ.get("REPRO_PALLAS_INTERPRET", "") == "1"
