"""Divisibility-aware sharding policy: TP over "model", FSDP over
("pod","data") — DESIGN.md §5.

jax rejects NamedShardings whose dims don't divide the mesh axis, so the
policy PROVES divisibility before sharding and falls back per-tensor:

* named rules first (embeddings vocab-sharded, attention projections
  column/row split, MoE expert dim, router replicated);
* generic fallback: largest dim divisible by the axis size;
* anything that doesn't divide is replicated on that axis — e.g.
  llama3.2-3b's 24 heads on a 16-way model axis keep head projections
  replicated while its d_ff=8192 still TP-shards (the policy operates
  per-tensor, so partial TP comes out naturally).

Stacked scan params carry a leading layer dim that is never sharded.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)      # ("pod","data") multi-pod
    fsdp_params: bool = True                    # shard params at rest
    # activation batch axes (data parallel)
    batch_axes: Tuple[str, ...] = ("data",)

    @staticmethod
    def for_mesh(mesh: Mesh, fsdp_params: bool = True) -> "ShardingPolicy":
        names = mesh.axis_names
        if "pod" in names:
            return ShardingPolicy(fsdp_axes=("pod", "data"),
                                  batch_axes=("pod", "data"),
                                  fsdp_params=fsdp_params)
        return ShardingPolicy(fsdp_params=fsdp_params)


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _spec_for_leaf(path: str, shape: Sequence[int], mesh: Mesh,
                   pol: ShardingPolicy) -> P:
    tp_n = _axis_size(mesh, pol.tp_axis)
    fsdp_n = _axis_size(mesh, pol.fsdp_axes)
    ndims = len(shape)
    entries: list = [None] * ndims
    if ndims == 0:
        return P()

    # leading dims of stacked/scanned blocks are layer dims — skip them:
    # heuristic: paths under blocks/pairs/groups have stacked leaves
    first_ok = 0
    if re.search(r"(blocks|pairs|groups)", path) and ndims >= 2:
        first_ok = 1
    cand_dims = list(range(first_ok, ndims))

    def try_assign(dim: int, axes) -> bool:
        n = _axis_size(mesh, axes)
        if dim in cand_dims and entries[dim] is None and shape[dim] % n == 0 \
                and shape[dim] >= n:
            entries[dim] = axes if isinstance(axes, str) else tuple(axes)
            return True
        return False

    # ---- named rules (TP placement) -----------------------------------
    leaf = path.split("/")[-1]
    tp_done = False
    if leaf in ("embed",):
        tp_done = try_assign(first_ok + 0, pol.tp_axis)       # vocab dim
    elif leaf in ("lm_head",):
        tp_done = try_assign(ndims - 1, pol.tp_axis)          # vocab dim
    elif leaf in ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_gates",
                  "w_if"):
        tp_done = try_assign(ndims - 1, pol.tp_axis)          # column split
    elif leaf in ("wo", "w_down", "w_out"):
        tp_done = try_assign(ndims - 2, pol.tp_axis)          # row split
    elif leaf == "router":
        tp_done = True                                         # replicate
    elif re.search(r"moe", path) and ndims >= 3:
        # (L?, E, D, F) expert tensors: expert dim first, else F
        tp_done = (try_assign(first_ok, pol.tp_axis)
                   or try_assign(ndims - 1, pol.tp_axis))
    # generic fallback: largest divisible dim, preferring the last
    if not tp_done:
        for dim in sorted(cand_dims, key=lambda d: (-shape[d], -d)):
            if shape[dim] >= 2 * tp_n and try_assign(dim, pol.tp_axis):
                break

    # ---- FSDP placement over the remaining dims ------------------------
    if pol.fsdp_params and fsdp_n > 1:
        for dim in sorted(cand_dims, key=lambda d: (-shape[d], d)):
            if try_assign(dim, pol.fsdp_axes):
                break

    return P(*entries)


def param_shardings(params_shape: Any, mesh: Mesh,
                    pol: Optional[ShardingPolicy] = None) -> Any:
    """Pytree of NamedShardings for a (possibly abstract) param pytree."""
    pol = pol or ShardingPolicy.for_mesh(mesh)

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [walk(v, f"{prefix}/{i}") for i, v in enumerate(tree)]
            return type(tree)(out)
        spec = _spec_for_leaf(prefix, tree.shape, mesh, pol)
        return NamedSharding(mesh, spec)

    return walk(params_shape)


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    pol: Optional[ShardingPolicy] = None
                    ) -> Dict[str, NamedSharding]:
    """Shardings for the step inputs of one (arch, shape) cell."""
    pol = pol or ShardingPolicy.for_mesh(mesh)
    B = shape.global_batch
    bn = _axis_size(mesh, pol.batch_axes)
    batch_axes = pol.batch_axes if B % bn == 0 else (
        pol.batch_axes[:1] if B % _axis_size(mesh, pol.batch_axes[:1]) == 0
        else None)
    bspec = batch_axes if batch_axes else None

    def nd(*entries):
        return NamedSharding(mesh, P(*entries))

    out: Dict[str, NamedSharding] = {}
    if shape.kind == "train":
        out["tokens"] = nd(bspec, None)
        out["labels"] = nd(bspec, None)
    elif shape.kind == "prefill":
        out["tokens"] = nd(bspec, None)
    else:
        out["token"] = nd(bspec)
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = nd(bspec, None, None)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = nd(bspec, None, None)
    return out


def cache_shardings(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                    batch: int, pol: Optional[ShardingPolicy] = None,
                    batch_axes_tree: Optional[Any] = None) -> Any:
    """Shardings for a decode cache pytree.

    KV time axis shards over "model" (the flash-decoding KV-split: each
    model shard owns a slice of the context; XLA inserts the partial-
    softmax combine).  Batch shards over the data axes when divisible.
    Recurrent state (B, D) shards D over "model".

    ``batch_axes_tree`` (from ``model.cache_batch_axes``) names each
    leaf's batch dim — stacked caches are (L, B, T, ...) while flat
    recurrent states are (B, ...).
    """
    pol = pol or ShardingPolicy.for_mesh(mesh)
    tp = pol.tp_axis
    tp_n = _axis_size(mesh, tp)
    bn = _axis_size(mesh, pol.batch_axes)
    b_ax = pol.batch_axes if batch % bn == 0 else None

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in tree.items()}
        shp = tree.shape
        nd = len(shp)
        if nd == 0:
            return NamedSharding(mesh, P())
        key = prefix.split("/")[-1]
        if key in ("length", "enc_len"):
            return NamedSharding(mesh, P(b_ax))
        b_dim = 1
        if batch_axes_tree is not None:
            b_dim = batch_axes_tree.get(key, 1)
        if b_dim >= nd or shp[b_dim] != batch:
            b_dim = next((d for d in range(nd) if shp[d] == batch), None)
        entries: list = [None] * nd
        if b_dim is not None:
            entries[b_dim] = b_ax
        t_dim = None if b_dim is None else b_dim + 1
        if (t_dim is not None and nd >= t_dim + 3
                and shp[t_dim] % tp_n == 0 and shp[t_dim] >= tp_n):
            entries[t_dim] = tp                 # KV-seq split
        elif (entries[-1] is None and shp[-1] % tp_n == 0
                and shp[-1] >= 2 * tp_n):
            entries[-1] = tp
        return NamedSharding(mesh, P(*entries))

    return walk(cache_shape)
