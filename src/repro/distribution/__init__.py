"""Distribution layer: divisibility-aware sharding policy (TP × FSDP) and
shard_map collectives (KV-seq-split flash-decoding, compressed cross-pod
gradient reduction)."""
from repro.distribution.sharding import (
    ShardingPolicy, param_shardings, input_shardings, cache_shardings,
)

__all__ = ["ShardingPolicy", "param_shardings", "input_shardings",
           "cache_shardings"]
