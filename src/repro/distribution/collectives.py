"""shard_map collectives: KV-seq-split flash-decoding + compressed
cross-pod gradient reduction.

``sharded_decode_attention`` is the distribution-level twin of the
decode kernel: the KV cache is sharded along its TIME axis over the
"model" mesh axis; every shard runs flash-decode over its local chunk
and the partial (out, m, l) triples merge with the log-sum-exp combine —
the same merge the kernel uses across VMEM chunks, lifted to ICI.  This
is how a 67B × 32k × 128-request cache (~0.8 TiB) decodes across 256
chips without any single chip holding the context.

``compressed_psum_grads`` wires grad_compress into a cross-pod psum:
int8 quantize (+error feedback) → int32 psum over "pod" → dequantize.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.training.grad_compress import dequantize, quantize_error_feedback

NEG_INF = -1e30


def sharded_decode_attention(q, k, v, q_positions, kv_positions, *,
                             mesh: Mesh, kv_axis: str = "model",
                             window: int = 0):
    """q: (B,H,Dh) replicated over kv_axis; k,v: (B,T,Hkv,Dh) with T
    sharded over kv_axis; kv_positions (B,T) sharded alike."""

    def local(qb, kb, vb, qp, kp):
        out, m, l = decode_attention_ref(
            qb, kb, vb, q_positions=qp, kv_positions=kp, window=window,
            return_lse=True)
        # merge partial softmax stats across KV shards (flash-decoding)
        m_max = lax.pmax(m, kv_axis)                      # (B,H)
        w = jnp.exp(m - m_max) * l
        num = lax.psum(out.astype(jnp.float32) * w[..., None], kv_axis)
        den = lax.psum(w, kv_axis)
        den = jnp.where(den == 0.0, 1.0, den)
        return (num / den[..., None]).astype(qb.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, None, None), P(None, kv_axis, None, None),
                  P(None, kv_axis, None, None), P(None),
                  P(None, kv_axis)),
        out_specs=P(None, None, None),
        check_rep=False,
    )(q, k, v, q_positions, kv_positions)


def compressed_psum_grads(grads: Any, err_state: Any, *, mesh: Mesh,
                          axis: str = "pod") -> Tuple[Any, Any]:
    """int8(+EF) all-reduce of a gradient pytree over the slow axis.

    Inputs are assumed replicated over ``axis`` up to their local shard
    values (per-pod partial gradients); returns (mean grads, new error
    state).  2× less DCN traffic than bf16, 4× less than f32.
    """
    n = mesh.shape[axis]

    def local(g_tree, e_tree):
        def one(g, e):
            q, scale, new_err = quantize_error_feedback(g, e)
            q32 = lax.psum(q.astype(jnp.int32), axis)
            # conservative shared scale: max over pods
            s = lax.pmax(scale, axis)
            return dequantize(q32, s) / n, new_err
        flat_g, treedef = jax.tree.flatten(g_tree)
        flat_e = jax.tree.leaves(e_tree)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
                jax.tree.unflatten(treedef, [o[1] for o in outs]))

    spec = jax.tree.map(lambda _: P(), grads)
    return shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec), out_specs=(spec, spec),
        check_rep=False,
    )(grads, err_state)
