"""Coordinator bookkeeping shared by the real Processor backend.

* ``BatchState`` tracks per-(query, node) results and macro-node
  completion over the consolidated batch; thread-safe; supports per-query
  wavefront promotion for tool nodes, per-request pipelining for LLM
  nodes, and macro-barrier readiness (checkpoint restore / barrier mode).
  Listeners registered with ``add_listener`` get every (query, node)
  result as it lands — the event feed driving the ToolDispatcher and the
  replanning monitor without polling.
* ``PlanBoard`` is the mutable view of an ExecutionPlan's per-worker node
  sequences.  Workers *claim* nodes in sequence order; a node is released
  only once all its LLM-DAG parents are claimed, so the global claim
  order is a topological order of the LLM DAG — which is what makes a
  mid-run replan splice (claimed prefix + re-solved tail) a valid plan.
"""
from __future__ import annotations

from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple)

from repro.core.graphspec import GraphSpec, LLMDag
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.state import WorkerContext
from repro.debugsync import named_condition


class BatchState:
    """Thread-safe per-(query, node) result store for one batch run.

    ``queries_of`` (node id → global query indices) restricts each node
    to a subset of the batch — the multi-template mega-DAG case, where a
    namespaced node serves only its own template's query slice.  By
    default every node serves every query (single template).  A node
    with an EMPTY query set is macro-complete from the start.
    """

    def __init__(self, graph: GraphSpec, n_queries: int,
                 queries_of: Optional[Dict[str, Sequence[int]]] = None):
        self.lock = named_condition("BatchState.lock")
        self.graph = graph               # guarded-by: self.lock
        self.n = n_queries               # guarded-by: self.lock
        self.results: Dict[Tuple[int, str], str] = {}  # guarded-by: self.lock
        self.node_done_count: Dict[str, int] = {v: 0 for v in graph.nodes}  # guarded-by: self.lock
        if queries_of is None:
            self.queries_of = {v: list(range(n_queries)) for v in graph.nodes}  # guarded-by: self.lock
        else:
            self.queries_of = {v: sorted(queries_of.get(v, ()))
                               for v in graph.nodes}
        self._query_sets = {v: set(qs) for v, qs in self.queries_of.items()}  # guarded-by: self.lock
        self.expected = {v: len(qs) for v, qs in self.queries_of.items()}  # guarded-by: self.lock
        # zero-query nodes (an empty template slice) are done at birth
        self.macro_done: Set[str] = {  # guarded-by: self.lock
            v for v, n in self.expected.items() if n == 0}
        # per-query SLO priority (DESIGN.md §10.3); absent = 0 = batch
        self.query_priority: Dict[int, int] = {}  # guarded-by: self.lock
        # append-only, registered before the workers start; set_result
        # iterates a snapshot outside the lock by design
        self._listeners: List[Callable[[int, str], None]] = []

    # ------------------------------------------------------------------
    def priority_of(self, q: int) -> int:
        """SLO-lane priority of query ``q`` (0 = batch lane)."""
        with self.lock:
            return self.query_priority.get(q, 0)

    def extend(self, graph: GraphSpec, n_new: int,
               queries_of: Optional[Dict[str, Sequence[int]]] = None,
               priorities: Optional[Dict[int, int]] = None) -> None:
        """Grow the batch mid-run (a session graft; DESIGN.md §10.2).

        ``graph`` must be a supergraph of the current one: existing
        nodes keep their ids, query slices and results; new nodes (and
        the ``n_new`` new queries) are added with fresh bookkeeping.
        Zero-query new nodes are macro-complete at birth, exactly as in
        ``__init__``.
        """
        with self.lock:
            missing = set(self.graph.nodes) - set(graph.nodes)
            if missing:
                raise ValueError(
                    f"graft graph dropped existing nodes: {sorted(missing)}")
            self.graph = graph
            self.n += n_new
            for v in graph.nodes:
                if v in self.queries_of:
                    continue
                qs = sorted((queries_of or {}).get(v, ()))
                self.queries_of[v] = qs
                self._query_sets[v] = set(qs)
                self.expected[v] = len(qs)
                self.node_done_count[v] = 0
                if not qs:
                    self.macro_done.add(v)
            self.query_priority.update(priorities or {})
            self.lock.notify_all()

    # ------------------------------------------------------------------
    def add_listener(self, fn: Callable[[int, str], None]) -> None:
        """Register a per-result observer ``fn(query, node)``.

        Called after every ``set_result`` *outside* the state lock, on
        whichever thread produced the result — observers must be cheap
        and non-blocking (enqueue + wake, not work).
        """
        self._listeners.append(fn)

    def set_result(self, q: int, node: str, value: str) -> bool:
        """Record one (query, node) result. Returns True if the macro node
        just completed (all queries done)."""
        with self.lock:
            if (q, node) in self.results:
                return False
            self.results[(q, node)] = value
            self.node_done_count[node] += 1
            macro = self.node_done_count[node] == self.expected[node]
            if macro:
                self.macro_done.add(node)
            # per-result wakeup: pipelined workers wait on single-query
            # readiness, not just macro completion
            self.lock.notify_all()
        for fn in self._listeners:
            fn(q, node)
        return macro

    # requires: self.lock
    def queries_for_locked(self, node: str) -> List[int]:
        """``queries_for`` for callers already inside ``self.lock``."""
        return list(self.queries_of[node])

    def queries_for(self, node: str) -> List[int]:
        """Global query indices ``node`` serves (grows only by graft)."""
        with self.lock:
            return list(self.queries_of[node])

    def serves(self, q: int, node: str) -> bool:
        """True when query ``q`` belongs to ``node``'s template slice."""
        with self.lock:
            return q in self._query_sets[node]

    def is_macro_done(self, node: str) -> bool:
        """True once every query of ``node`` has a result."""
        with self.lock:
            return node in self.macro_done

    def macro_ready(self, node: str) -> bool:
        """All parents complete for ALL queries (LLM barrier readiness)."""
        with self.lock:
            return all(p in self.macro_done
                       for p in self.graph.parents(node))

    def query_ready(self, q: int, node: str) -> bool:
        """All parents complete for ONE query (wavefront readiness)."""
        with self.lock:
            return all((q, p) in self.results
                       for p in self.graph.parents(node))

    def wait_macro_ready(self, node: str, timeout: float = 120.0) -> None:
        with self.lock:
            ok = self.lock.wait_for(
                lambda: all(p in self.macro_done
                            for p in self.graph.parents(node)),
                timeout=timeout)
            if not ok:
                raise TimeoutError(f"deps of {node!r} never completed")

    def upstream(self, q: int) -> Dict[str, str]:
        with self.lock:
            return {node: val for (qq, node), val in self.results.items()
                    if qq == q}

    def all_done(self) -> bool:
        with self.lock:
            return len(self.macro_done) == len(self.graph.nodes)


class PlanBoard:
    """Claimable per-worker node sequences with atomic tail replacement.

    The GPU workers pull their next node from here instead of a frozen
    list, which is what lets the replanning monitor swap every worker's
    unclaimed tail mid-run.  Overflow from failed workers also routes
    through the board (claimable by any surviving worker).
    """

    def __init__(self, plan: ExecutionPlan, dag: LLMDag, num_workers: int):
        self.lock = named_condition("PlanBoard.lock")
        self.dag = dag                   # guarded-by: self.lock
        self.W = num_workers
        self.seqs: List[List[str]] = plan.worker_sequences(num_workers)  # guarded-by: self.lock
        self.claimed: List[str] = []     # guarded-by: self.lock
        self.claimed_set: Set[str] = set()  # guarded-by: self.lock
        self.claim_chain: List[List[str]] = [  # guarded-by: self.lock
            [] for _ in range(num_workers)]
        self.overflow: List[str] = []    # guarded-by: self.lock
        self.dead: Set[int] = set()      # guarded-by: self.lock
        self.splices = 0                 # guarded-by: self.lock

    # ------------------------------------------------------------------
    # requires: self.lock
    def _releasable(self, nid: str) -> bool:
        return all(p in self.claimed_set for p in self.dag.parents(nid))

    # requires: self.lock
    def _claim_locked(self, wid: int, nid: str) -> str:
        self.claimed.append(nid)
        self.claimed_set.add(nid)
        self.claim_chain[wid].append(nid)
        self.lock.notify_all()
        return nid

    def try_claim(self, wid: int) -> Optional[str]:
        """Next node for worker ``wid``: own sequence head if releasable,
        else a releasable overflow node. None if nothing claimable now."""
        with self.lock:
            while self.seqs[wid] and self.seqs[wid][0] in self.claimed_set:
                self.seqs[wid].pop(0)
            if self.seqs[wid] and self._releasable(self.seqs[wid][0]):
                return self._claim_locked(wid, self.seqs[wid].pop(0))
            for i, nid in enumerate(self.overflow):
                if nid in self.claimed_set:
                    continue
                if self._releasable(nid):
                    self.overflow.pop(i)
                    return self._claim_locked(wid, nid)
            return None

    def abandon(self, wid: int) -> None:
        """A (simulated-)failed worker returns its unclaimed tail."""
        with self.lock:
            rest = [n for n in self.seqs[wid] if n not in self.claimed_set]
            self.seqs[wid] = []
            self.dead.add(wid)
            self.overflow.extend(rest)
            self.lock.notify_all()

    def exhausted(self, wid: int) -> bool:
        """True when worker ``wid`` can never claim anything again.

        Deliberately global: an idle worker must stay parked (not exit)
        while ANY node is unclaimed, because a mid-run replan splice may
        hand it part of the new tail.
        """
        with self.lock:
            return len(self.claimed) == len(self.dag.node_ids)

    def remaining(self) -> int:
        with self.lock:
            return len(self.dag.node_ids) - len(self.claimed)

    def planned_assignments(self) -> Dict[str, int]:
        """Worker each still-unclaimed node is currently planned on —
        the 'before' side of a splice's assignment diff (overflow nodes
        have no planned worker and are omitted)."""
        with self.lock:
            return {n: w for w, seq in enumerate(self.seqs) for n in seq
                    if n not in self.claimed_set}

    # ------------------------------------------------------------------
    # requires: self.lock
    def contexts_locked(self) -> Tuple[WorkerContext, ...]:
        """Live per-worker contexts implied by each claim chain.
        Caller must hold ``self.lock``."""
        out = []
        for chain in self.claim_chain:
            ctx = WorkerContext()
            for nid in chain:
                ctx = ctx.after(nid, self.dag.spec(nid).model)
            out.append(ctx)
        return tuple(out)

    def contexts(self) -> Tuple[WorkerContext, ...]:
        with self.lock:
            return self.contexts_locked()

    # requires: self.lock
    def claimed_prefix_epochs_locked(self) -> List[Epoch]:
        """The executed prefix as singleton epochs in claim order — valid
        by construction because claims follow DAG topological order.
        Caller must hold ``self.lock``."""
        chains = self.claim_chain
        return [Epoch([[nid]],
                      [next(w for w in range(self.W)
                            if nid in chains[w])])
                for nid in self.claimed]

    def claimed_prefix_epochs(self) -> List[Epoch]:
        with self.lock:
            return self.claimed_prefix_epochs_locked()

    # requires: self.lock
    def _splice_locked(self, tail: ExecutionPlan) -> None:
        seqs = tail.worker_sequences(self.W)
        self.seqs = [[n for n in seqs[w] if n not in self.claimed_set]
                     for w in range(self.W)]
        # tail work planned onto an abandoned worker would be
        # unclaimable (try_claim only reads seqs[wid] + overflow) —
        # reroute it through overflow for the survivors
        orphaned: List[str] = []
        for w in self.dead:
            orphaned.extend(self.seqs[w])
            self.seqs[w] = []
        self.overflow = [n for n in self.overflow
                         if n not in self.claimed_set
                         and not any(n in s for s in self.seqs)
                         and n not in orphaned] + orphaned
        self.splices += 1
        self.lock.notify_all()

    def splice(self, tail: ExecutionPlan) -> None:
        """Replace every worker's unclaimed tail with ``tail``'s sequences.

        The caller must have solved ``tail`` from an initial SystemState
        whose done-set equals the current claimed set.
        """
        with self.lock:
            self._splice_locked(tail)

    def graft(self, dag: LLMDag, tail: ExecutionPlan) -> None:
        """Atomically adopt a grown LLM DAG and splice in its re-solved
        tail (a session graft; DESIGN.md §10.2).

        ``dag`` must contain every already-claimed node (claims and claim
        chains survive); the tail covers the unclaimed remainder —
        including the freshly grafted nodes — so parked workers wake with
        claimable work the moment the splice publishes.
        """
        with self.lock:
            missing = self.claimed_set - set(dag.node_ids)
            if missing:
                raise ValueError(
                    f"graft DAG dropped claimed nodes: {sorted(missing)}")
            self.dag = dag
            self._splice_locked(tail)
