"""Coordinator bookkeeping shared by the real Processor backend.

Tracks per-(query, node) results and macro-node completion over the
consolidated batch; thread-safe; supports per-query wavefront promotion
for tool nodes and macro-barrier readiness for (batched) LLM nodes.
"""
from __future__ import annotations

import threading
from typing import Dict, Set, Tuple

from repro.core.graphspec import GraphSpec


class BatchState:
    def __init__(self, graph: GraphSpec, n_queries: int):
        self.graph = graph
        self.n = n_queries
        self.lock = threading.Condition()
        self.results: Dict[Tuple[int, str], str] = {}
        self.node_done_count: Dict[str, int] = {v: 0 for v in graph.nodes}
        self.macro_done: Set[str] = set()

    # ------------------------------------------------------------------
    def set_result(self, q: int, node: str, value: str) -> bool:
        """Record one (query, node) result. Returns True if the macro node
        just completed (all queries done)."""
        with self.lock:
            if (q, node) in self.results:
                return False
            self.results[(q, node)] = value
            self.node_done_count[node] += 1
            if self.node_done_count[node] == self.n:
                self.macro_done.add(node)
                self.lock.notify_all()
                return True
            return False

    def macro_ready(self, node: str) -> bool:
        """All parents complete for ALL queries (LLM barrier readiness)."""
        with self.lock:
            return all(p in self.macro_done
                       for p in self.graph.parents(node))

    def query_ready(self, q: int, node: str) -> bool:
        """All parents complete for ONE query (tool wavefront readiness)."""
        with self.lock:
            return all((q, p) in self.results
                       for p in self.graph.parents(node))

    def wait_macro_ready(self, node: str, timeout: float = 120.0) -> None:
        with self.lock:
            ok = self.lock.wait_for(
                lambda: all(p in self.macro_done
                            for p in self.graph.parents(node)),
                timeout=timeout)
            if not ok:
                raise TimeoutError(f"deps of {node!r} never completed")

    def upstream(self, q: int) -> Dict[str, str]:
        with self.lock:
            return {node: val for (qq, node), val in self.results.items()
                    if qq == q}

    def all_done(self) -> bool:
        with self.lock:
            return len(self.macro_done) == len(self.graph.nodes)
