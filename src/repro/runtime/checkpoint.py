"""Back-compat shim — checkpointing moved into the durable job layer.

The one-shot snapshot API (``save_batch_state`` / ``load_batch_state``)
and the signature journal now live in ``repro.runtime.jobstore``
(DESIGN.md §12.2); import from there.
"""
from repro.runtime.jobstore import (CheckpointError, load_batch_state,
                                    save_batch_state)

__all__ = ["CheckpointError", "load_batch_state", "save_batch_state"]
