"""Workflow-state checkpointing: restart a half-finished batch run.

Atomic JSON snapshots of the (query, node) → result map.  On resume, the
Processor pre-populates BatchState and workers skip completed macro
nodes — the batch-analytics analogue of training checkpoint/restart.
"""
from __future__ import annotations

import json
import os
import tempfile

from repro.runtime.coordinator import BatchState


def save_batch_state(state: BatchState, path: str) -> None:
    with state.lock:
        payload = {
            "n_queries": state.n,
            "results": [[q, node, val]
                        for (q, node), val in state.results.items()],
        }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)                      # atomic commit
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_batch_state(state: BatchState, path: str) -> int:
    """Populate ``state`` from a snapshot. Returns #results restored."""
    with open(path) as f:
        payload = json.load(f)
    with state.lock:
        n_queries = state.n
    if payload["n_queries"] != n_queries:
        raise ValueError("checkpoint was taken with a different batch size")
    n = 0
    for q, node, val in payload["results"]:
        state.set_result(int(q), node, val)
        n += 1
    return n
