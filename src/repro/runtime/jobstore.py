"""Durable job store: the batch survives kill -9 (DESIGN.md §12.2).

Two persistence layers, both keyed so a restart re-pays NOTHING that
already finished:

* ``JobStore`` — an append-only JSONL *signature journal*.  Every
  completed (query, node) result is journaled under its
  consolidation-layer signature (``signature_map``), the same identity
  request dedup merges on — so one journal line covers every logical
  query that shares the physical execution, cross-template dedup
  included, and a RE-consolidated batch after restart maps its
  (query, node) pairs back onto the journaled lines by recomputing the
  same signatures.  Each line carries its own checksum: a torn tail
  from kill -9 mid-write is detected and dropped, never half-applied.
  Writes happen incrementally from a ``BatchState`` listener (flushed
  per line, fsynced every ``fsync_every`` records and on close), so
  the journal is as fresh as the last completed result.

* ``save_batch_state`` / ``load_batch_state`` — one-shot atomic JSON
  snapshots of the whole (query, node) → result map (the original
  ``runtime.checkpoint`` API, absorbed here).  ``load_batch_state``
  VALIDATES every entry against the live graph — unknown node ids, out
  of range queries, or malformed entries raise ``CheckpointError``
  with a diagnostic (path, expected vs found) instead of silently
  poisoning ``BatchState``'s completion accounting.

Resume contract: the journal stores *values by signature*; replaying a
signature into a (query, node) pair is only sound when the pair's
output is a deterministic function of the signature.  That holds for
tool nodes and temperature-0 LLM nodes by construction (the influence
tuple IS the signature); sampled (temperature > 0) LLM nodes get a
per-query suffix so they never replay across queries.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Dict, Optional, Set, Tuple

from repro.debugsync import named_lock
from repro.runtime.coordinator import BatchState

_MAGIC = "halo-jobstore"
_VERSION = 1


class CheckpointError(ValueError):
    """A checkpoint/journal failed validation against the live run."""


# ---------------------------------------------------------------------------
# signature keys
# ---------------------------------------------------------------------------

def _key(sig: str) -> str:
    # journal lines store a fixed-width digest, not the raw signature
    # (LLM signatures embed whole influence tuples and can be huge)
    return hashlib.blake2b(sig.encode(), digest_size=16).hexdigest()


def signature_map(cons) -> Dict[Tuple[int, str], str]:
    """(query, node) → durable journal key, from the consolidation
    layer's signature table (DESIGN.md §8.1).

    Signatures live in the base-id space (multi-template consolidation
    suffixes a lineage digest), so re-consolidating the same
    (template, bindings) submissions after a restart reproduces the
    same keys — which is what lets the journal be replayed into a
    fresh ``BatchState``.  Sampled LLM nodes (temperature > 0) get a
    per-query suffix: their outputs are not functions of the signature
    alone, so they must never replay across queries.
    """
    out: Dict[Tuple[int, str], str] = {}
    for nid, m in cons.macros.items():
        per_query = m.spec.is_llm() and m.spec.temperature > 0
        for local, q in enumerate(m.queries):
            key = _key(m.unique_signatures[m.signature_of_query[local]])
            out[(q, nid)] = f"{key}#q{q}" if per_query else key
    return out


# ---------------------------------------------------------------------------
# the signature journal
# ---------------------------------------------------------------------------

def _line_checksum(key: str, node: str, value: str) -> str:
    payload = f"{key}|{node}|{value}".encode()
    return hashlib.blake2b(payload, digest_size=8).hexdigest()


class JobStore:
    """Append-only signature journal with kill -9-tolerant loading."""

    def __init__(self, path: str, fsync_every: int = 32):
        self.path = path
        self.fsync_every = max(int(fsync_every), 1)
        self._lock = named_lock("JobStore._lock")
        self._seen: Dict[str, str] = {}       # guarded-by: self._lock
        self._replaying: Set[str] = set()     # guarded-by: self._lock
        self._writes_since_sync = 0           # guarded-by: self._lock
        self._f = None                        # guarded-by: self._lock
        self.dropped_lines = 0                # torn/corrupt tail lines
        self.restored_results = 0             # guarded-by: self._lock
        self.re_executed: Set[str] = set()    # guarded-by: self._lock
        self._truncate_to: Optional[int] = None   # torn-tail repair offset
        self._needs_newline = False           # valid tail missing its "\n"
        with self._lock:
            self._load()
            self._at_open = frozenset(self._seen)
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        if self._truncate_to is not None:
            # repair before reopening for append: without this, the next
            # record() would concatenate onto the torn fragment, merging
            # into one invalid line and poisoning every later load
            with open(path, "r+b") as tf:
                tf.truncate(self._truncate_to)
                tf.flush()
                os.fsync(tf.fileno())
        self._f = open(path, "a", encoding="utf-8")
        if self._needs_newline:
            self._f.write("\n")
            self._f.flush()
        if not self._at_open and self._f.tell() == 0:
            header = {"magic": _MAGIC, "version": _VERSION}
            self._f.write(json.dumps(header) + "\n")
            self._f.flush()

    # ------------------------------------------------------------- load
    # requires: self._lock
    def _load(self) -> None:
        try:
            with open(self.path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return
        raw_lines = data.splitlines(keepends=True)
        offset = 0          # bytes consumed; trails the current line
        valid_end = 0       # end of the last intact line
        for i, raw in enumerate(raw_lines):
            offset += len(raw)
            last = i == len(raw_lines) - 1
            try:
                line = raw.decode("utf-8").strip()
                entry = json.loads(line) if line else None
            except (UnicodeDecodeError, json.JSONDecodeError):
                # torn write (kill -9 mid-append): only tolerable at the
                # tail — anywhere else the file is corrupt, not torn
                if last:
                    self.dropped_lines += 1
                    continue
                raise CheckpointError(
                    f"corrupt jobstore {self.path}: line {i + 1} is not "
                    "valid JSON (and is not the torn tail)") from None
            if entry is None:                   # blank line
                valid_end = offset
                continue
            if "magic" in entry:
                if (entry.get("magic") != _MAGIC
                        or entry.get("version") != _VERSION):
                    raise CheckpointError(
                        f"jobstore {self.path}: header {entry!r} does not "
                        f"match {_MAGIC} v{_VERSION}")
                valid_end = offset
                continue
            key, node = entry.get("k"), entry.get("n", "")
            value, check = entry.get("v"), entry.get("c")
            if key is None or value is None \
                    or check != _line_checksum(key, node, value):
                if last:
                    self.dropped_lines += 1
                    continue
                raise CheckpointError(
                    f"jobstore {self.path}: line {i + 1} failed its "
                    "checksum (and is not the torn tail)")
            self._seen[key] = value
            valid_end = offset
        if valid_end < len(data):
            self._truncate_to = valid_end
        elif data and not data.endswith(b"\n"):
            # whole file intact but the final newline never landed:
            # terminate it so the first appended record starts clean
            self._needs_newline = True

    # ---------------------------------------------------------- journal
    def record(self, key: str, node: str, value: str) -> None:
        """Journal one completed result under its signature key.

        Repeat keys within a run are the normal fan-out of one physical
        execution across the logical queries that share it — journaled
        once.  A key that was already in the journal at open means the
        work was RE-executed after a resume (the restore should have
        replayed it); counted in ``re_executed``, which resume tests
        pin to zero.
        """
        with self._lock:
            if self._f is None:
                # closed (or still opening): a straggler worker thread
                # that outlives the shutdown join can fire the listener
                # after close() — drop the write instead of crashing
                return
            if key in self._replaying:
                return                  # our own restore replay, not work
            if key in self._at_open:
                self.re_executed.add(key)
                return
            if key in self._seen:
                return                  # same-run fan-out of one execution
            self._seen[key] = value
            self._append_locked(key, node, value)

    # requires: self._lock
    def _append_locked(self, key: str, node: str, value: str) -> None:
        entry = {"k": key, "n": node, "v": value,
                 "c": _line_checksum(key, node, value)}
        self._f.write(json.dumps(entry) + "\n")
        self._f.flush()
        self._writes_since_sync += 1
        if self._writes_since_sync >= self.fsync_every:
            os.fsync(self._f.fileno())
            self._writes_since_sync = 0

    def lookup(self, key: str) -> Optional[str]:
        with self._lock:
            return self._seen.get(key)

    # ----------------------------------------------------------- resume
    def restore_into(self, state: BatchState,
                     sig_of: Dict[Tuple[int, str], str]) -> int:
        """Replay every journaled signature into ``state``: each
        (query, node) whose key is journaled gets its stored value, so
        neither workers nor the dispatcher re-execute it.  Returns the
        number of results restored."""
        with self._lock:
            seen = dict(self._seen)
        hits = [(q, nid, key) for (q, nid), key in sig_of.items()
                if key in seen]
        keys = {key for _, _, key in hits}
        with self._lock:
            self._replaying |= keys
        n = 0
        try:
            for q, nid, key in hits:
                with state.lock:
                    present = (q, nid) in state.results
                if not present:
                    state.set_result(q, nid, seen[key])
                    n += 1
        finally:
            with self._lock:
                self._replaying -= keys
                self.restored_results += n
        return n

    # ---------------------------------------------------------- summary
    def summary(self) -> Dict[str, int]:
        with self._lock:
            return {
                "completed_signatures": len(self._seen),
                "restored_signatures": len(self._at_open),
                "restored_results": self.restored_results,
                "re_executed_signatures": len(self.re_executed),
                "dropped_lines": self.dropped_lines,
            }

    def close(self) -> None:
        with self._lock:
            if self._f is None:
                return
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None


# ---------------------------------------------------------------------------
# one-shot snapshots (the absorbed runtime.checkpoint API)
# ---------------------------------------------------------------------------

def save_batch_state(state: BatchState, path: str) -> None:
    """Atomic JSON snapshot of the (query, node) → result map."""
    with state.lock:
        payload = {
            "n_queries": state.n,
            "results": [[q, node, val]
                        for (q, node), val in state.results.items()],
        }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())       # data durable before the rename
        os.replace(tmp, path)                      # atomic commit
        try:
            dfd = os.open(d, os.O_RDONLY)          # make the rename durable
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        except OSError:
            pass            # directory fsync unsupported on this platform
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_batch_state(state: BatchState, path: str) -> int:
    """Populate ``state`` from a snapshot. Returns #results restored.

    Every entry is validated against the LIVE graph before anything is
    applied: a stale or corrupt checkpoint raises ``CheckpointError``
    naming the path and the expected-vs-found mismatch, instead of
    silently ``set_result``-ing entries that would inflate completion
    counts for the wrong nodes.
    """
    try:
        with open(path) as f:
            payload = json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointError(
            f"corrupt checkpoint {path}: not valid JSON ({e})") from None
    if not isinstance(payload, dict) or \
            not isinstance(payload.get("results"), list) or \
            "n_queries" not in payload:
        found = (sorted(payload) if isinstance(payload, dict)
                 else type(payload).__name__)
        raise CheckpointError(
            f"corrupt checkpoint {path}: expected "
            "{'n_queries': ..., 'results': [[q, node, value], ...]}, "
            f"found keys {found}")
    with state.lock:
        n_queries = state.n
        known = set(state.graph.nodes)
    if payload["n_queries"] != n_queries:
        raise CheckpointError(
            f"checkpoint {path} was taken with a different batch size: "
            f"expected {n_queries} queries, found {payload['n_queries']}")
    entries = []
    for i, entry in enumerate(payload["results"]):
        try:
            q, node, val = entry
            q = int(q)
        except (TypeError, ValueError):
            raise CheckpointError(
                f"corrupt checkpoint {path}: entry {i} is {entry!r}, "
                "expected [query, node, value]") from None
        if node not in known:
            sample = ", ".join(sorted(known)[:4])
            raise CheckpointError(
                f"checkpoint {path}: entry {i} references node {node!r} "
                f"which is not in the live graph (expected one of "
                f"{len(known)} nodes: {sample}, ...) — stale checkpoint "
                "from a different graph?")
        if not state.serves(q, node):
            raise CheckpointError(
                f"checkpoint {path}: entry {i} assigns query {q} to node "
                f"{node!r}, but the live graph's template slice for that "
                f"node is {state.queries_for(node)[:8]}... — stale "
                "checkpoint from a different batch?")
        entries.append((q, node, val))
    # validate-then-apply: nothing is written unless EVERY entry passed
    n = 0
    for q, node, val in entries:
        state.set_result(q, node, val)
        n += 1
    return n
