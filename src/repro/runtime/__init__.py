"""Halo Processor (paper §5): event-driven execution over heterogeneous
CPU/GPU workers, with a discrete-event simulated backend (paper-scale
numbers) and a real backend (tiny JAX models + minidb, semantics checks).
``ProcessorSession`` (DESIGN.md §10) is the streaming entry point:
queries submitted mid-run graft into the running mega-DAG.
"""
from repro.runtime.events import RunReport, TaskRecord
from repro.runtime.faults import (FaultInjector, FaultPlan,
                                  TransientToolError)
from repro.runtime.jobstore import CheckpointError, JobStore
from repro.runtime.opwise import OpWiseSimulator
from repro.runtime.simulator import SimulatedProcessor, OnlineSimulator
from repro.runtime.session import (ProcessorConfig, ProcessorSession,
                                   QueryHandle)
from repro.runtime.processor import RealProcessor
from repro.runtime.replan import OnlineOptimizer
from repro.runtime.migrate import KVMigrator

__all__ = ["RunReport", "TaskRecord", "SimulatedProcessor",
           "OnlineSimulator", "RealProcessor", "OpWiseSimulator",
           "OnlineOptimizer", "KVMigrator", "ProcessorConfig",
           "ProcessorSession", "QueryHandle", "JobStore",
           "CheckpointError", "FaultPlan", "FaultInjector",
           "TransientToolError"]
