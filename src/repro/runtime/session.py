"""Session-style streaming Processor API (DESIGN.md §10).

A ``ProcessorSession`` deletes the micro-batch boundary: ``open()``
starts the worker/dispatcher loop ONCE, and every later ``submit()``
grafts the arriving queries into the RUNNING mega-DAG instead of
waiting for the next ``RealProcessor.run()`` call (DESIGN.md §10.1).
A graft (DESIGN.md §10.2):

1. consolidates the new (template, bindings) pair into the live
   ``MultiConsolidatedGraph`` via its incremental ``graft()`` — the new
   nodes join the EXISTING signature table (tool requests an in-flight
   node already issued are aliased, not re-run) and the existing
   warm-KV alias groups;
2. grows the live ``BatchState`` (new queries + nodes after birth);
3. re-solves the remaining LLM DAG from the board's live
   (claimed, contexts) state and splices the new tail via
   ``PlanBoard.graft`` — parked workers wake with claimable work, and
   the engines admit the grafted requests mid-decode;
4. returns per-query ``QueryHandle`` futures.

Per-request SLO classes (DESIGN.md §10.3) ride along: ``submit(...,
slo="interactive")`` tags the queries with the lane's priority, which
flows into the solver's priority-weighted epoch packing AND the
engines' priority admission — an interactive request preempts
batch-lane admission under KV-pool pressure, never vice versa.

``RealProcessor.run()`` is a thin one-shot wrapper over this class:
open → submit_consolidated → drain → report → close.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core import HARDWARE, PAPER_MODELS
from repro.core.coalesce import CoalesceTable
from repro.core.consolidate import (ConsolidatedGraph,
                                    MultiConsolidatedGraph,
                                    consolidate_multi)
from repro.core.cost_model import CostModel
from repro.core.graphspec import GraphSpec
from repro.core.plan import ExecutionPlan
from repro.core.solver import EpochDPSolver, SolverConfig
from repro.core.state import SLO_CLASSES, SLOClass, SystemState
from repro.debugsync import named_lock
from repro.runtime.coordinator import BatchState, PlanBoard
from repro.runtime.events import RunReport, TaskRecord
from repro.runtime.executors import (EngineHost, GPUWorkerThread,
                                     ToolDispatcher)
from repro.runtime.faults import FaultInjector, FaultPlan
from repro.runtime.jobstore import (JobStore, load_batch_state,
                                    signature_map)
from repro.runtime.migrate import KVMigrator
from repro.workloads.tools import ToolRuntime

# engine counters that accumulate monotonically (reported as per-run
# deltas so persistent hosts don't leak prior runs into each report)
_ENGINE_COUNTERS = ("prefill_tokens_saved", "admission_waves",
                    "priority_jumps", "pages_shared", "tokens_reused",
                    "coalesced_requests", "decode_tokens",
                    "pages_migrated_in", "pages_migrated_out",
                    "migrate_seconds", "h2d_bytes", "d2h_bytes",
                    "view_rebuilds")


@dataclass
class ProcessorConfig:
    """Construction knobs shared by ``RealProcessor`` and
    ``ProcessorSession`` (the former 11 loose ``__init__`` kwargs).

    ``priority_admission=False`` is the FIFO A/B control: SLO classes
    are accepted but their priorities are zeroed, so engine admission
    and epoch packing reduce exactly to the unweighted behaviour.
    """

    num_workers: int = 2
    cpu_slots: int = 8
    coalescing: bool = True
    seed: int = 0
    # cap generation length in tests (CPU real mode); None = node spec
    decode_cap: Optional[int] = None
    pipelining: bool = True
    engine_kwargs: Optional[Dict[str, Any]] = None
    # migrate moved nodes' warm KV on plan splices (off = A/B control)
    kv_migration: bool = True
    # workers claim at most this many incomplete nodes ahead (None =
    # unlimited) so pipelined claims can't outrun completions and
    # starve the mid-run replanning window
    claim_ahead: Optional[int] = None
    # feed SLO-class priorities into solver packing + engine admission;
    # False = FIFO control arm (DESIGN.md §10.3)
    priority_admission: bool = True
    # durable signature journal (DESIGN.md §12.2): completed results are
    # journaled incrementally and replayed on the next run at this path,
    # so a killed batch resumes without re-executing finished signatures
    jobstore_path: Optional[str] = None
    jobstore_fsync_every: int = 32
    # deterministic fault injection (DESIGN.md §12.3); None = off
    faults: Optional[FaultPlan] = None
    # bounded re-dispatch of TransientToolError tool calls
    tool_retries: int = 2


class QueryHandle:
    """Per-query future returned by ``ProcessorSession.submit()``
    (DESIGN.md §10.1), mirroring the engine's ``RequestHandle``.

    ``result()`` blocks for the query's full per-node output dict;
    ``ttft()`` is the session-level time-to-first-token proxy — seconds
    from submit to the query's FIRST LLM node result landing;
    ``add_done_callback`` fires when every node the query serves has a
    result (inline if already done).
    """

    def __init__(self, query: int, slo: SLOClass, nodes: Sequence[str],
                 llm_nodes: Sequence[str], state: BatchState,
                 submit_t: float):
        self.query = query
        self.slo = slo
        self._state = state
        self._submit_t = submit_t
        self._llm = set(llm_nodes)
        self._remaining = set(nodes)                # guarded-by: self._lock
        self._lock = named_lock("QueryHandle._lock")
        self._event = threading.Event()
        self._first_llm_t: Optional[float] = None   # guarded-by: self._lock
        # error latch: written once under _lock, read freely after the
        # completion event fires (the event is the publication barrier)
        self._error: Optional[BaseException] = None     # swap-only
        self._callbacks: List[Callable[["QueryHandle"], None]] = []  # guarded-by: self._lock
        if not self._remaining:                 # empty template slice
            self._event.set()

    # ------------------------------------------------------- plumbing
    def _note(self, node: str) -> None:
        """One (query, node) result landed (idempotent per node)."""
        with self._lock:
            if node not in self._remaining:
                return
            self._remaining.discard(node)
            if node in self._llm and self._first_llm_t is None:
                self._first_llm_t = time.perf_counter()
            done = not self._remaining
            cbs = list(self._callbacks) if done else []
        if done:
            self._event.set()
            for fn in cbs:
                fn(self)

    def _fail(self, err: BaseException) -> None:
        with self._lock:
            if self._error is None:
                self._error = err
            cbs = list(self._callbacks)
        self._event.set()
        for fn in cbs:
            fn(self)

    # ------------------------------------------------------------ API
    def done(self) -> bool:
        """True once every node result landed (or the session failed)."""
        return self._event.is_set()

    def exception(self) -> Optional[BaseException]:
        """The session error that failed this query, if any."""
        return self._error

    def add_done_callback(self,
                          fn: Callable[["QueryHandle"], None]) -> None:
        """Call ``fn(self)`` on completion; inline if already done."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def result(self, timeout: float = 600.0) -> Dict[str, str]:
        """Block for this query's ``{node_id: output}`` dict."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"query {self.query} incomplete after {timeout}s")
        if self._error is not None:
            raise self._error
        with self._state.lock:
            return {node: val
                    for (q, node), val in self._state.results.items()
                    if q == self.query}

    def ttft(self) -> Optional[float]:
        """Seconds from submit to the first LLM-node result (the
        session-level TTFT proxy scored against the SLO class's
        ``ttft_target_s``); None until a first token lands."""
        with self._lock:
            if self._first_llm_t is None:
                return None
            return self._first_llm_t - self._submit_t

    def first_result_at(self) -> Optional[float]:
        """``time.perf_counter()`` stamp of the first LLM-node result —
        lets a driver score TTFT against an ARRIVAL clock it owns (e.g.
        a query that queued behind a batch boundary before submit)."""
        with self._lock:
            return self._first_llm_t


class ProcessorSession:
    """Long-lived streaming Processor: one worker/dispatcher loop,
    many ``submit()`` calls grafted into the running mega-DAG
    (DESIGN.md §10.1).
    """

    def __init__(self, model_configs: Dict[str, ModelConfig],
                 tools: ToolRuntime,
                 config: Optional[ProcessorConfig] = None):
        self.config = config or ProcessorConfig()
        self.model_configs = model_configs
        self.tools = tools
        self.W = self.config.num_workers
        # lifecycle
        self._opened = False
        self._started = False
        self._closed = False
        self._stop = threading.Event()
        # serializes submits (bootstrap/graft) against the monitor's
        # replan heartbeat; also guards the session topology refs below
        self._graft_lock = named_lock("ProcessorSession._graft_lock")
        # error latch: swapped in by the monitor/worker side, read by
        # the submitting side (drain re-raises it)
        self._error: Optional[BaseException] = None     # swap-only
        # populated by open()/bootstrap
        self.hosts: Optional[List[EngineHost]] = None
        self._own_hosts = False
        self.optimizer = None
        self._cons: Optional[ConsolidatedGraph] = None  # guarded-by: self._graft_lock
        self.graph: Optional[GraphSpec] = None      # guarded-by: self._graft_lock
        self.state: Optional[BatchState] = None
        self.board: Optional[PlanBoard] = None
        self.dispatcher: Optional[ToolDispatcher] = None
        self.workers: List[GPUWorkerThread] = []
        self.migrator: Optional[KVMigrator] = None
        self.jobstore: Optional[JobStore] = None    # swap-only
        self.injector: Optional[FaultInjector] = None
        # (query, node) -> journal key; whole-dict swap on graft so the
        # journal listener reads it lock-free
        self._sig_of: Dict = {}                     # swap-only
        self._monitor: Optional[threading.Thread] = None
        self._rlock = named_lock("ProcessorSession._rlock")
        self._records: List[TaskRecord] = []        # guarded-by: self._rlock
        self._t0 = 0.0
        self._cm: Optional[CostModel] = None        # guarded-by: self._graft_lock
        self._solver_config = SolverConfig(num_workers=self.W)
        self._node_prio: Dict[str, float] = {}      # guarded-by: self._graft_lock
        self._handles: Dict[int, QueryHandle] = {}  # guarded-by: self._graft_lock
        self._plan_name = ""                        # guarded-by: self._graft_lock
        self._restored = 0                          # guarded-by: self._graft_lock
        self._base_counters: Dict[str, int] = {}    # guarded-by: self._graft_lock
        self._base_replans = 0                      # guarded-by: self._graft_lock
        self.grafts = 0                             # guarded-by: self._graft_lock

    # --------------------------------------------------------- lifecycle
    def open(self, hosts: Optional[List[EngineHost]] = None,
             optimizer=None) -> "ProcessorSession":
        """Attach (or create) engine hosts and an optional
        ``OnlineOptimizer``; the worker/dispatcher loop starts lazily on
        the first submission.  Persistent ``hosts`` keep resident models
        and warm KV pages across sessions; the optimizer's calibration
        likewise compounds."""
        if self._opened:
            raise RuntimeError("session already open")
        self._own_hosts = hosts is None
        if hosts is None:
            hosts = [EngineHost(self.model_configs, seed=self.config.seed,
                                engine_kwargs=self.config.engine_kwargs)
                     for _ in range(self.W)]
        if len(hosts) != self.W:
            raise ValueError(f"need {self.W} hosts, got {len(hosts)}")
        self.hosts = hosts
        self.optimizer = optimizer
        self._opened = True
        return self

    def __enter__(self) -> "ProcessorSession":
        if not self._opened:
            self.open()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------- submission
    def _capped(self, template: GraphSpec) -> GraphSpec:
        cap = self.config.decode_cap
        if cap is None:
            return template
        nodes = [n.with_(max_new_tokens=min(n.max_new_tokens, cap))
                 if n.is_llm() else n for n in template.nodes.values()]
        return GraphSpec(template.name, nodes, template.edges)

    def _slo(self, slo) -> SLOClass:
        if isinstance(slo, SLOClass):
            return slo
        try:
            return SLO_CLASSES[slo]
        except KeyError:
            raise ValueError(f"unknown SLO class {slo!r} "
                             f"(have: {sorted(SLO_CLASSES)})") from None

    def submit(self, template: GraphSpec,
               bindings: Sequence[Dict[str, str]],
               slo="batch") -> List[QueryHandle]:
        """Consolidate ``bindings`` over ``template`` INTO the running
        mega-DAG and return one ``QueryHandle`` per query.

        The first call bootstraps the session (consolidate + solve +
        start workers); every later call grafts (DESIGN.md §10.2): the
        new queries share the live signature table and warm aliases, the
        remaining DAG is re-solved with the live worker contexts, and
        the spliced tail reaches the engines mid-decode.  ``slo`` picks
        the service lane (DESIGN.md §10.3).
        """
        if not self._opened:
            raise RuntimeError("open() the session before submitting")
        if self._closed:
            raise RuntimeError("session is closed")
        slo_cls = self._slo(slo)
        with self._graft_lock:
            if not self._started:
                cons = consolidate_multi([(self._capped(template),
                                           bindings)])
                return self._bootstrap(cons, plan=None, slo=slo_cls)
            return self._graft(template, bindings, slo_cls)

    def submit_consolidated(self, cons: ConsolidatedGraph,
                            plan: Optional[ExecutionPlan] = None, *,
                            graph: Optional[GraphSpec] = None,
                            resume_from: Optional[str] = None,
                            die_after: Optional[Dict[int, int]] = None,
                            slo="batch") -> List[QueryHandle]:
        """Bootstrap the session from an ALREADY consolidated batch (the
        one-shot ``RealProcessor.run()`` path): an optional pre-solved
        ``plan``, a ``decode_cap``-rewritten ``graph`` override, a
        checkpoint to resume from, and simulated worker failures."""
        if not self._opened:
            raise RuntimeError("open() the session before submitting")
        with self._graft_lock:
            if self._started:
                raise RuntimeError(
                    "submit_consolidated only bootstraps; use submit() "
                    "to graft into a running session")
            return self._bootstrap(cons, plan, slo=self._slo(slo),
                                   graph=graph, resume_from=resume_from,
                                   die_after=die_after)

    # ------------------------------------------------------- bootstrap
    def _priority(self, slo_cls: SLOClass) -> int:
        return slo_cls.priority if self.config.priority_admission else 0

    # requires: self._graft_lock
    def _build_cm(self) -> CostModel:
        return CostModel(self.graph, HARDWARE["h200"], PAPER_MODELS,
                         batch_sizes=self._cons.batch_sizes(),
                         use_migration=self.config.kv_migration,
                         warm_aliases=self._cons.warm_aliases())

    # requires: self._graft_lock
    def _register_handles(self, queries: Sequence[int],
                          slo_cls: SLOClass) -> List[QueryHandle]:
        now = time.perf_counter()
        out = []
        for q in queries:
            nodes = [nid for nid in self.graph.nodes
                     if self.state.serves(q, nid)]
            llm = [nid for nid in nodes if self.graph.nodes[nid].is_llm()]
            h = QueryHandle(q, slo_cls, nodes, llm, self.state, now)
            self._handles[q] = h
            out.append(h)
        # results that already landed (checkpoint restore, or a race
        # with the listener) are replayed; _note is idempotent per node
        with self.state.lock:
            landed = [(q, node) for (q, node) in self.state.results
                      if q in self._handles]
        for q, node in landed:
            self._handles[q]._note(node)
        return out

    # runs-on: any
    def _on_result(self, q: int, node: str) -> None:
        h = self._handles.get(q)
        if h is not None:
            h._note(node)

    # runs-on: any
    def _journal_result(self, q: int, node: str) -> None:
        """BatchState listener → durable journal: every landed result is
        recorded under its consolidation signature (fires OUTSIDE the
        state lock, so re-acquiring it to read the value is safe)."""
        key = self._sig_of.get((q, node))
        if key is None:
            return                  # node without a signature mapping
        with self.state.lock:
            val = self.state.results.get((q, node))
        if val is not None:
            self.jobstore.record(key, node, str(val))

    # requires: self._graft_lock
    def _bootstrap(self, cons: ConsolidatedGraph,
                   plan: Optional[ExecutionPlan], slo: SLOClass,
                   graph: Optional[GraphSpec] = None,
                   resume_from: Optional[str] = None,
                   die_after: Optional[Dict[int, int]] = None
                   ) -> List[QueryHandle]:
        cfg = self.config
        self._cons = cons
        self.graph = graph if graph is not None else cons.template
        self.state = BatchState(self.graph, cons.n_queries,
                                queries_of=cons.queries_map())
        prio = self._priority(slo)
        with self.state.lock:
            self.state.query_priority = {q: prio
                                         for q in range(cons.n_queries)}
        if prio:
            self._node_prio = {nid: float(prio)
                               for nid in self.graph.llm_nodes()}
        if resume_from:
            self._restored = load_batch_state(self.state, resume_from)
        if cfg.faults is not None:
            self.injector = FaultInjector(cfg.faults)
        if cfg.jobstore_path:
            # open + replay BEFORE the journal listener attaches: the
            # restore's own set_result events must not be re-journaled
            self.jobstore = JobStore(cfg.jobstore_path,
                                     fsync_every=cfg.jobstore_fsync_every)
            self._sig_of = signature_map(cons)
            self._restored += self.jobstore.restore_into(self.state,
                                                         self._sig_of)
            self.state.add_listener(self._journal_result)

        self._t0 = time.perf_counter()
        if self.optimizer is not None:
            self.optimizer.bind_graph(self.graph)
            self.optimizer.solver_config.num_workers = self.W
            # replans must price placement moves the way THIS session
            # executes them: no migration credit when migration is off
            self.optimizer.cm.use_migration = cfg.kv_migration
            self._cm = self.optimizer.cm
            self._base_replans = self.optimizer.replans
            if self._node_prio:
                self.optimizer.node_priorities = dict(self._node_prio)
        else:
            self._cm = self._build_cm()
        if plan is None:
            plan = EpochDPSolver(self.graph.llm_dag(), self._cm,
                                 replace(self._solver_config),
                                 priorities=self._node_prio).solve()
        self._plan_name = plan.scheduler_name
        self.board = PlanBoard(plan, self.graph.llm_dag(), self.W)
        if self.optimizer is not None:
            self.optimizer.attach_plan(plan)

        self.dispatcher = ToolDispatcher(
            self.graph, self.state, cons.bindings, self.tools,
            self._records, self._rlock, self._t0,
            cpu_slots=cfg.cpu_slots, coalescing=cfg.coalescing,
            optimizer=self.optimizer, persistent=True,
            faults=self.injector, tool_retries=cfg.tool_retries)
        self.dispatcher.start()

        self._base_counters = self._engine_totals(self.hosts)
        for h in self.hosts:                    # per-session watermark
            for e in h.engines():
                e.reset_peak_batch()

        if cfg.kv_migration:
            # no optimizer -> no replanning, but workers still pull warm
            # lineage from peers at claim time (cost-model decision
            # falls back to migrate-on-hit without a cm)
            self.migrator = KVMigrator(
                self.graph, self.hosts,
                cost_model=(self.optimizer.cm
                            if self.optimizer is not None else None))

        # explicit die_after wins; the fault plan's kill_worker fills in
        # the rest (both routes end in PlanBoard.abandon + overflow)
        die = dict(die_after or {})
        if self.injector is not None:
            for w in range(self.W):
                after = self.injector.die_after(w)
                if after is not None:
                    die.setdefault(w, after)
        self.workers = [
            GPUWorkerThread(w, self.board, self.graph, self.state,
                            cons.bindings, self.hosts[w], self._records,
                            self._rlock, self._t0,
                            die_after=die.get(w),
                            pipelining=cfg.pipelining,
                            optimizer=self.optimizer,
                            migrator=self.migrator,
                            claim_ahead=cfg.claim_ahead,
                            stop_event=self._stop,
                            faults=self.injector)
            for w in range(self.W)]
        self.state.add_listener(self._on_result)
        handles = self._register_handles(range(cons.n_queries), slo)
        if self.optimizer is not None:
            # admission-time pass: a queued (forced) splice — or a plan
            # already known-drifted from a prior run's calibration —
            # re-places work and migrates warm KV before any claim
            self.optimizer.maybe_replan(self.board,
                                        migrator=self.migrator)
        for wk in self.workers:
            wk.start()
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True,
                                         name="session-monitor")
        self._monitor.start()
        self._started = True
        return handles

    # runs-on: session-monitor
    def _monitor_loop(self) -> None:
        """Error watch + the replanning heartbeat (drift evaluation runs
        on this thread, exactly like the one-shot monitor loop)."""
        while not self._stop.is_set():
            err = next((wk.error for wk in self.workers if wk.error),
                       None) or self.dispatcher.error
            if err is not None and self._error is None:
                self._error = err
                with self.state.lock:
                    self.state.lock.notify_all()
            if self.optimizer is not None and self._error is None:
                # never replan concurrently with an in-progress graft:
                # the board's DAG and the optimizer's may briefly
                # disagree mid-graft, and a splice solved against the
                # wrong one would publish unclaimable nodes
                if self._graft_lock.acquire(blocking=False):
                    try:
                        self.optimizer.maybe_replan(
                            self.board, migrator=self.migrator)
                    except BaseException as e:
                        self._error = self._error or e
                        with self.state.lock:
                            self.state.lock.notify_all()
                    finally:
                        self._graft_lock.release()
            self._stop.wait(timeout=0.05)

    # ------------------------------------------------------------ graft
    # requires: self._graft_lock
    def _graft(self, template: GraphSpec,
               bindings: Sequence[Dict[str, str]],
               slo_cls: SLOClass) -> List[QueryHandle]:
        """Graft new queries into the running mega-DAG (DESIGN.md
        §10.2).  Caller holds ``_graft_lock``."""
        if not isinstance(self._cons, MultiConsolidatedGraph):
            raise RuntimeError(
                "grafting needs a multi-consolidated session (bootstrap "
                "via submit(), not a single-template batch)")
        err = self._error
        if err is not None:
            raise err
        new_ids, offset = self._cons.graft([(self._capped(template),
                                             bindings)])
        graph = self._cons.template
        n_new = len(bindings)
        prio = self._priority(slo_cls)
        queries = list(range(offset, offset + n_new))

        # 1. state grows first: workers/dispatcher must find the new
        #    queries' bookkeeping before any new node becomes claimable
        self.state.extend(graph, n_new,
                          queries_of=self._cons.queries_map(),
                          priorities={q: prio for q in queries})
        self.graph = graph
        for wk in self.workers:
            wk.rebind(graph)
        if self.migrator is not None:
            self.migrator.graph = graph
        if self.jobstore is not None:
            # grafted queries may repeat journaled signatures: swap in
            # the grown map, replay hits (the journal listener ignores
            # its own replay via the store's replaying set)
            self._sig_of = signature_map(self._cons)
            self._restored += self.jobstore.restore_into(self.state,
                                                         self._sig_of)

        # 2. cost-model adoption: grown batch sizes, merged warm-alias
        #    groups, accumulated SLO priority mass
        if prio:
            self._node_prio.update(
                {nid: float(prio) for nid in new_ids
                 if graph.nodes[nid].is_llm()})
        if self.optimizer is not None:
            self.optimizer.adopt_graft(graph, self._cons.batch_sizes(),
                                       self._cons.warm_aliases(),
                                       self._node_prio)
            self._cm = self.optimizer.cm
        else:
            self._cm = self._build_cm()

        # 3. re-solve the remaining DAG from the LIVE system state:
        #    claimed nodes are done, worker contexts carry their warm KV
        new_dag = graph.llm_dag()
        with self.board.lock:
            done = frozenset(self.board.claimed_set)
            contexts = self.board.contexts_locked()
        tail = EpochDPSolver(
            new_dag, self._cm, replace(self._solver_config),
            priorities=self._node_prio,
        ).solve(initial=SystemState(done, contexts))

        # 4. migrate warm KV for moved old nodes, then publish: parked
        #    workers wake on the board notify with claimable work
        if self.migrator is not None:
            self.migrator.migrate_for_splice(self.board, tail)
        self.board.graft(new_dag, tail)
        self.dispatcher.rebind(graph)
        self.grafts += 1

        # 5. keep the drift monitor coherent: the live plan becomes
        #    claimed-prefix + grafted tail, with the prefix marked
        #    evaluated (history has no solver-predicted cost)
        base = self._plan_name or "halo-dp"
        self._plan_name = base if base.endswith("+graft") \
            else base + "+graft"
        if self.optimizer is not None:
            prefix = self.board.claimed_prefix_epochs()
            spliced = ExecutionPlan(epochs=prefix + tail.epochs,
                                    predicted_cost=tail.predicted_cost,
                                    scheduler_name=self._plan_name)
            spliced.validate(new_dag)
            self.optimizer.attach_plan(spliced, fresh=False,
                                       evaluated_prefix=len(prefix))
        return self._register_handles(queries, slo_cls)

    # ------------------------------------------------------------ drain
    def drain(self, timeout: float = 600.0) -> None:
        """Block until every submitted query's every node has a result
        (or the session failed)."""
        if not self._started:
            return
        state = self.state
        with state.lock:
            state.lock.wait_for(
                lambda: (len(state.macro_done) == len(state.graph.nodes)
                         or self._error is not None
                         or any(wk.error for wk in self.workers)
                         or self.dispatcher.error is not None),
                timeout=timeout)
        err = self._error \
            or next((wk.error for wk in self.workers if wk.error), None) \
            or self.dispatcher.error
        if err is not None:
            raise err
        with state.lock:
            missing = set(state.graph.nodes) - state.macro_done
        if missing:
            raise RuntimeError(f"run incomplete; missing {sorted(missing)}")

    def close(self) -> None:
        """Stop workers, dispatcher and monitor; join every thread; shut
        down session-owned hosts.  Idempotent; leaks no threads."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self.board is not None:
            with self.board.lock:
                self.board.lock.notify_all()
        if self.state is not None:
            with self.state.lock:
                self.state.lock.notify_all()
        for wk in self.workers:
            wk.join(timeout=60)
        if self.dispatcher is not None:
            self.dispatcher.stop()
            self.dispatcher.join(timeout=60)
        if self._monitor is not None:
            self._monitor.join(timeout=60)
        if self.jobstore is not None:       # after joins: no more writes
            self.jobstore.close()
        if self._own_hosts and self.hosts is not None:
            for h in self.hosts:
                h.shutdown()

    # ---------------------------------------------------------- report
    @staticmethod
    def _engine_totals(hosts: List[EngineHost]) -> Dict[str, int]:
        engines = [e for h in hosts for e in h.engines()]
        out = {k: sum(getattr(e.stats, k) for e in engines)
               for k in _ENGINE_COUNTERS}
        out["model_switches"] = sum(h.switches for h in hosts)
        return out

    @staticmethod
    # requires: BatchState.lock
    def _cross_template_stats(cons: ConsolidatedGraph,
                              table: CoalesceTable) -> Dict[str, int]:
        """Runtime cross-template coalescing: physical tool executions
        whose logical requesters span >= 2 templates (the merges only a
        multi-template mega-DAG makes possible)."""
        merged_tasks = 0
        merged_requests = 0
        tasks = list(table.completed.values()) + list(table.pending.values())
        for task in tasks:
            if not task.requesters:
                continue
            # only requesters from a DIFFERENT template than the one
            # whose request ran the physical execution count as
            # cross-template merges — same-template coalescing on a
            # spanning task is ordinary dedup, not a mega-DAG win
            owner = cons.template_of[task.requesters[0][1]]
            crossed = sum(1 for _, nid in task.requesters
                          if cons.template_of[nid] != owner)
            if crossed:
                merged_tasks += 1
                merged_requests += crossed
        return {"cross_template_merged_tasks": merged_tasks,
                "cross_template_merged_requests": merged_requests}

    def report(self) -> RunReport:
        """Build the RunReport for everything this session executed so
        far (same layout as the one-shot ``RealProcessor.run()``:
        coalescing stats, per-run engine-counter deltas, splice/replan
        and migration summaries, plus session-only ``grafts``)."""
        if not self._started:
            raise RuntimeError("nothing submitted yet")
        cons, dispatcher = self._cons, self.dispatcher
        plan_name = self._plan_name or "halo-session"
        if self.optimizer is not None and self.optimizer.plan is not None:
            plan_name = self.optimizer.plan.scheduler_name
        report = RunReport(
            name=plan_name, makespan=time.perf_counter() - self._t0,
            records=self._records, num_queries=cons.n_queries,
            num_workers=self.W)
        with self.state.lock:           # the table is guarded by it
            report.coalesce_stats = {
                "tool_logical": dispatcher.table.logical_requests,
                "tool_physical": dispatcher.table.physical_executions,
                "tool_dedup_ratio": dispatcher.table.dedup_ratio,
                "restored_results": self._restored,
            }
            if cons.n_templates > 1:
                report.coalesce_stats.update(
                    self._cross_template_stats(cons, dispatcher.table))
            results = dict(self.state.results)
        report.extra["results"] = {           # type: ignore[assignment]
            f"{q}:{node}": val
            for (q, node), val in sorted(results.items())}
        # per-run deltas against the at-open totals: persistent hosts
        # must not re-report earlier sessions' counts
        totals = self._engine_totals(self.hosts)
        for key, cur in totals.items():
            report.extra[key] = max(cur - self._base_counters.get(key, 0),
                                    0)
        engines = [e for h in self.hosts for e in h.engines()]
        # per-run gauge: watermarks were reset at bootstrap, so the max
        # is THIS session's peak concurrency, not an earlier run's
        report.extra["peak_batch"] = max(
            (e.stats.peak_batch for e in engines), default=0)
        report.extra["cpu_gpu_overlap_s"] = round(
            report.cpu_gpu_overlap(), 6)
        with self.board.lock:
            report.extra["plan_splices"] = self.board.splices
        report.extra["grafts"] = self.grafts
        if self.jobstore is not None:
            report.extra["jobstore"] = (      # type: ignore[assignment]
                self.jobstore.summary())
        if self.injector is not None:
            report.extra["faults"] = (        # type: ignore[assignment]
                self.injector.summary())
            with dispatcher._retry_lock:
                report.extra["tool_retries"] = dispatcher.retries_used
        if self.optimizer is not None:
            report.extra["replans"] = (self.optimizer.replans
                                       - self._base_replans)
            report.extra["calibration"] = (   # type: ignore[assignment]
                self.optimizer.calibration_summary())
        if self.migrator is not None:
            report.extra["migration"] = (     # type: ignore[assignment]
                self.migrator.summary())
        return report
