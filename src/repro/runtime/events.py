"""Execution records + run reports shared by both Processor backends."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class TaskRecord:
    node: str
    kind: str                     # "llm" | "tool"
    worker: str                   # "gpu0".. | "cpu"
    start: float
    end: float
    batch: int = 1                # physical batch executed
    instance: int = 0             # batch-plan instance (online mode)
    info: str = ""

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclass
class RunReport:
    name: str = ""
    makespan: float = 0.0
    records: List[TaskRecord] = field(default_factory=list)
    num_queries: int = 0
    num_workers: int = 0
    coalesce_stats: Dict[str, float] = field(default_factory=dict)
    extra: Dict[str, float] = field(default_factory=dict)
    # online mode
    query_completion: List[float] = field(default_factory=list)

    # ------------------------------------------------------------------
    def results(self) -> Dict[str, str]:
        """Per-(query, node) outputs as ``"{q}:{node}" -> text`` — the
        typed accessor for what used to be ``extra["results"]`` reads.
        Empty for simulated runs (no real outputs to report)."""
        return dict(self.extra.get("results", {}))

    def migration_summary(self) -> Optional[Dict[str, float]]:
        """The KV migrator's counters for this run, or None when the run
        executed without a migrator (``kv_migration=False``)."""
        mig = self.extra.get("migration")
        return dict(mig) if mig is not None else None

    # ------------------------------------------------------------------
    def gpu_busy(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for r in self.records:
            if r.kind == "llm":
                out[r.worker] = out.get(r.worker, 0.0) + r.duration
        return out

    def gpu_seconds(self) -> float:
        """Cumulative GPU usage ∫U(t)dt — the Fig. 11 cost proxy."""
        return sum(self.gpu_busy().values())

    def cpu_seconds(self) -> float:
        return sum(r.duration for r in self.records if r.kind == "tool")

    def cpu_gpu_overlap(self) -> float:
        """Seconds during which tool (CPU) work and LLM (GPU) work ran
        concurrently — the fine-grained pipelining win (§5); 0 under a
        strict macro barrier on a linear llm→tool chain."""
        def merged(kind: str) -> List[List[float]]:
            iv = sorted([r.start, r.end] for r in self.records
                        if r.kind == kind)
            out: List[List[float]] = []
            for s, e in iv:
                if out and s <= out[-1][1]:
                    out[-1][1] = max(out[-1][1], e)
                else:
                    out.append([s, e])
            return out

        llm, tool = merged("llm"), merged("tool")
        i = j = 0
        total = 0.0
        while i < len(llm) and j < len(tool):
            s = max(llm[i][0], tool[j][0])
            e = min(llm[i][1], tool[j][1])
            if e > s:
                total += e - s
            if llm[i][1] < tool[j][1]:
                i += 1
            else:
                j += 1
        return total

    def utilization_trace(self, dt: float = 1.0) -> List[Tuple[float, float]]:
        """(t, fraction of GPU workers busy) samples."""
        if not self.records or self.num_workers == 0:
            return []
        horizon = self.makespan
        out = []
        llm = [r for r in self.records if r.kind == "llm"]
        t = 0.0
        while t < horizon:
            busy = sum(1 for r in llm if r.start < t + dt and r.end > t)
            out.append((t, min(busy / self.num_workers, 1.0)))
            t += dt
        return out

    def throughput_qps(self) -> float:
        if not self.query_completion:
            return self.num_queries / self.makespan if self.makespan else 0.0
        return len(self.query_completion) / max(self.query_completion)

    def summary(self) -> Dict[str, float]:
        return {
            "makespan_s": round(self.makespan, 3),
            "queries": self.num_queries,
            "gpu_seconds": round(self.gpu_seconds(), 3),
            "cpu_seconds": round(self.cpu_seconds(), 3),
            "qps": round(self.throughput_qps(), 4),
            **{k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in self.coalesce_stats.items()},
        }
