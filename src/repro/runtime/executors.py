"""Executors for the real Processor backend.

* EngineHost — a worker's model slot: at most one resident continuous-
  batching engine; ``submit()`` feeds requests into the engine's
  persistent loop (admitted mid-decode) and returns handles.
* GPUWorkerThread — a stateful GPU executor: runs its planned node
  sequence, submitting each node's requests into the resident engine and
  collecting handles; model switches drain/unload/load (the T_model
  event, measured).
* ToolDispatcher — bounded CPU pool with per-query wavefront promotion,
  depth-priority ordering and signature coalescing.
"""
from __future__ import annotations

import queue as _q
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.coalesce import CoalesceTable
from repro.core.graphspec import GraphSpec
from repro.core.parser import render
from repro.engine.engine import InferenceEngine, RequestHandle
from repro.engine.tokenizer import detokenize, tokenize
from repro.runtime.coordinator import BatchState
from repro.runtime.events import TaskRecord
from repro.workloads.tools import ToolRuntime


class EngineHost:
    """One worker's model slot: at most one resident engine."""

    def __init__(self, model_configs: Dict[str, ModelConfig], seed: int = 0):
        self.model_configs = model_configs
        self.seed = seed
        self._engines: Dict[str, InferenceEngine] = {}
        self.resident: Optional[str] = None
        self.switches = 0
        self.switch_seconds = 0.0

    def engine_for(self, model: str) -> InferenceEngine:
        if model not in self._engines:
            self._engines[model] = InferenceEngine(
                self.model_configs[model], seed=self.seed)
        eng = self._engines[model]
        if self.resident != model:
            if self.resident is not None:
                self._engines[self.resident].unload()
                self.switches += 1
            self.switch_seconds += eng.load()
            self.resident = model
        return eng

    def submit(self, model: str, prompts: Sequence[Sequence[int]], *,
               max_new_tokens: int = 16, temperature: float = 0.0,
               extras: Optional[List[Dict[str, Any]]] = None,
               ) -> List[RequestHandle]:
        """Submit prompts into the resident engine's persistent loop.

        Non-blocking: the requests join the engine's running decode batch
        (continuous batching); callers wait on the returned handles.
        """
        eng = self.engine_for(model)
        extras = extras or [{} for _ in prompts]
        return [eng.submit(p, max_new_tokens=max_new_tokens,
                           temperature=temperature, extra=e)
                for p, e in zip(prompts, extras)]

    def shutdown(self) -> None:
        """Stop every engine's loop thread (stats stay readable)."""
        for eng in self._engines.values():
            eng.shutdown()


class GPUWorkerThread(threading.Thread):
    def __init__(self, wid: int, seq: Sequence[str], graph: GraphSpec,
                 state: BatchState, bindings: Sequence[dict],
                 host: EngineHost, records: List[TaskRecord],
                 records_lock: threading.Lock, t0: float,
                 overflow: "_q.SimpleQueue[str]",
                 die_after: Optional[int] = None):
        super().__init__(daemon=True, name=f"gpu{wid}")
        self.wid = wid
        self.seq = list(seq)
        self.graph = graph
        self.state = state
        self.bindings = bindings
        self.host = host
        self.records = records
        self.records_lock = records_lock
        self.t0 = t0
        self.overflow = overflow
        self.die_after = die_after
        self.executed = 0
        self.error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _run_node(self, nid: str) -> None:
        spec = self.graph.nodes[nid]
        if nid in self.state.macro_done:
            return                                   # restored from checkpoint
        self.state.wait_macro_ready(nid)
        eng = self.host.engine_for(spec.model)
        prompts = []
        for q, b in enumerate(self.bindings):
            text = render(spec.prompt, b, self.state.upstream(q))
            prompts.append(tokenize(text, eng.cfg.vocab_size))
        ts = time.perf_counter() - self.t0
        handles = self.host.submit(
            spec.model, prompts, max_new_tokens=spec.max_new_tokens,
            temperature=spec.temperature)
        outs = [h.result() for h in handles]
        te = time.perf_counter() - self.t0
        with self.records_lock:
            self.records.append(TaskRecord(
                node=nid, kind="llm", worker=f"gpu{self.wid}",
                start=ts, end=te, batch=len(prompts)))
        for q, toks in enumerate(outs):
            self.state.set_result(q, nid, detokenize(toks))

    def run(self) -> None:
        """Process own sequence; pick up failed peers' overflow work the
        moment it is runnable (dependencies satisfied) — never block on a
        node another (possibly dead) worker was supposed to produce."""
        try:
            pending = list(self.seq)
            while not self.state.all_done():
                if (self.die_after is not None
                        and self.executed >= self.die_after):
                    for rest in pending:              # simulated failure
                        self.overflow.put(rest)
                    return
                ran = False
                # 1) own next node, if its deps are satisfied
                while pending and pending[0] in self.state.macro_done:
                    pending.pop(0)
                if pending and self.state.macro_ready(pending[0]):
                    self._run_node(pending.pop(0))
                    self.executed += 1
                    ran = True
                else:
                    # 2) a ready overflow node from a failed worker
                    stash = []
                    try:
                        while True:
                            nid = self.overflow.get_nowait()
                            if nid in self.state.macro_done:
                                continue
                            if self.state.macro_ready(nid):
                                self._run_node(nid)
                                self.executed += 1
                                ran = True
                                break
                            stash.append(nid)
                    except _q.Empty:
                        pass
                    for nid in stash:
                        self.overflow.put(nid)
                if not ran:
                    if not pending and self.overflow.empty():
                        return                        # nothing left for us
                    with self.state.lock:
                        self.state.lock.wait(timeout=0.05)
        except BaseException as e:                    # surfaced by Processor
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()


class ToolDispatcher(threading.Thread):
    """Promotes per-query tool tasks as their deps land; coalesces by
    canonical signature; executes on a bounded pool (backpressure)."""

    def __init__(self, graph: GraphSpec, state: BatchState,
                 bindings: Sequence[dict], tools: ToolRuntime,
                 records: List[TaskRecord], records_lock: threading.Lock,
                 t0: float, cpu_slots: int = 8, coalescing: bool = True):
        super().__init__(daemon=True, name="tool-dispatcher")
        self.graph = graph
        self.state = state
        self.bindings = bindings
        self.tools = tools
        self.records = records
        self.records_lock = records_lock
        self.t0 = t0
        self.pool = ThreadPoolExecutor(max_workers=cpu_slots)
        self.table = CoalesceTable(enabled=coalescing)
        self.dispatched: set = set()
        self.stop_flag = threading.Event()
        self.error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _execute(self, sig: str, op: str, args: str) -> None:
        try:
            ts = time.perf_counter() - self.t0
            result, _ = self.tools.execute(op, args)
            te = time.perf_counter() - self.t0
            with self.state.lock:
                requesters = self.table.complete(sig, result)
            with self.records_lock:
                self.records.append(TaskRecord(
                    node=requesters[0][1] if requesters else "?",
                    kind="tool", worker="cpu", start=ts, end=te,
                    batch=len(requesters), info=op))
            for q, nid in requesters:
                self.state.set_result(q, nid, str(result))
        except BaseException as e:
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()

    def _scan(self) -> int:
        """Dispatch every ready (query, tool) task. Returns #dispatched."""
        n = 0
        tool_nodes = sorted(
            self.graph.tool_nodes(),
            key=lambda t: len(self.graph.ancestors(t)))      # depth priority
        for nid in tool_nodes:
            spec = self.graph.nodes[nid]
            for q in range(self.state.n):
                key = (q, nid)
                if key in self.dispatched:
                    continue
                if (q, nid) in self.state.results:
                    self.dispatched.add(key)                 # checkpointed
                    continue
                if not self.state.query_ready(q, nid):
                    continue
                self.dispatched.add(key)
                args = render(spec.args, self.bindings[q],
                              self.state.upstream(q))
                with self.state.lock:
                    sig, needs_exec, cached = self.table.register(
                        spec.op, args, (q, nid))
                if cached is not None:
                    self.state.set_result(q, nid, str(cached))
                elif needs_exec:
                    self.pool.submit(self._execute, sig, spec.op, args)
                n += 1
        return n

    def run(self) -> None:
        try:
            while not self.stop_flag.is_set() and not self.state.all_done():
                self._scan()
                with self.state.lock:
                    self.state.lock.wait(timeout=0.02)
        finally:
            self.pool.shutdown(wait=True)
