"""Executors for the real Processor backend (DESIGN.md §7.1).

* EngineHost — a worker's model slot: at most one resident continuous-
  batching engine; ``submit()`` feeds requests into the engine's
  persistent loop (admitted mid-decode) and returns handles.
* GPUWorkerThread — a stateful GPU executor: claims its planned nodes
  from the PlanBoard and, in pipelined mode, submits each query's
  request the moment THAT query's deps land and publishes each result
  the moment its request retires (per-handle callbacks) — no macro
  barrier; barrier mode (``pipelining=False``) keeps the historical
  wait-all semantics for A/B comparison.
* ToolDispatcher — bounded CPU pool with per-query wavefront promotion,
  depth-priority ordering and signature coalescing; event-driven (woken
  by per-result listeners, incremental candidate scan) instead of a
  periodic full rescan.
"""
from __future__ import annotations

import queue as _q
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.coalesce import CoalesceTable
from repro.core.graphspec import GraphSpec
from repro.core.parser import render
from repro.debugsync import named_lock
from repro.engine.engine import InferenceEngine, RequestHandle
from repro.engine.tokenizer import detokenize, tokenize
from repro.runtime.coordinator import BatchState, PlanBoard
from repro.runtime.events import TaskRecord
from repro.runtime.faults import FaultInjector, TransientToolError
from repro.workloads.tools import ToolRuntime


class EngineHost:
    """One worker's model slot: at most one resident engine."""

    PROMPT_LOG_CAP = 16          # recent prompts kept per node (migration)

    def __init__(self, model_configs: Dict[str, ModelConfig], seed: int = 0,
                 engine_kwargs: Optional[Dict[str, Any]] = None):
        self.model_configs = model_configs
        self.seed = seed
        self.engine_kwargs = dict(engine_kwargs or {})
        # guards engine creation: the worker thread (engine_for) and the
        # migrator (engine_for_import, monitor thread) may first-touch
        # the same model concurrently during a mid-run splice
        self._engines_lock = named_lock("EngineHost._engines_lock")
        self._engines: Dict[str, InferenceEngine] = {}  # guarded-by: self._engines_lock
        # resident/switch bookkeeping belongs to the one worker thread
        # that owns this host's model slot — the migrator and monitor
        # go through engine_for_import, which never switches residency
        self.resident: Optional[str] = None             # guarded-by: gpu-worker
        self.switches = 0                               # guarded-by: gpu-worker
        self.switch_seconds = 0.0                       # guarded-by: gpu-worker
        # node -> recent prompt token tuples served here; the KVMigrator
        # reads this to know WHICH warm prefixes a replan strands when it
        # moves the node to another worker.  Persists with the host
        # across micro-batch runs (like the engines' warm pages).
        self._log_lock = named_lock("EngineHost._log_lock")
        self._prompt_log: Dict[str, List[tuple]] = {}   # guarded-by: self._log_lock

    def _get_engine(self, model: str) -> InferenceEngine:
        with self._engines_lock:
            if model not in self._engines:
                self._engines[model] = InferenceEngine(
                    self.model_configs[model], seed=self.seed,
                    **self.engine_kwargs)
            return self._engines[model]

    # runs-on: gpu-worker
    def engine_for(self, model: str) -> InferenceEngine:
        eng = self._get_engine(model)
        if self.resident != model:
            prev = (self.peek_engine(self.resident)
                    if self.resident is not None else None)
            if prev is not None:
                # unload/load run OUTSIDE _engines_lock: they move real
                # params and must not block the migrator's peek
                prev.unload()
                self.switches += 1
            self.switch_seconds += eng.load()
            self.resident = model
        return eng

    def peek_engine(self, model: str) -> Optional[InferenceEngine]:
        """The engine for ``model`` if one ever ran here, else None."""
        with self._engines_lock:
            return self._engines.get(model)

    def engine_for_import(self, model: str) -> InferenceEngine:
        """Get (or create) ``model``'s engine WITHOUT making it resident:
        importing migrated KV pages must not trigger a model switch —
        pages and the radix tree live outside the loaded params."""
        return self._get_engine(model)

    # ------------------------------------------------------- prompt log
    def log_prompts(self, nid: str, prompts) -> None:
        """Record the token prompts ``nid`` just submitted here."""
        with self._log_lock:
            log = self._prompt_log.setdefault(nid, [])
            for p in prompts:
                t = tuple(int(x) for x in p)
                if t in log:
                    log.remove(t)                # refresh recency
                log.append(t)
            del log[:-self.PROMPT_LOG_CAP]

    def prompts_for(self, nid: str) -> List[tuple]:
        with self._log_lock:
            return list(self._prompt_log.get(nid, ()))

    # runs-on: gpu-worker
    def submit(self, model: str, prompts: Sequence[Sequence[int]], *,
               max_new_tokens: int = 16, temperature: float = 0.0,
               extras: Optional[List[Dict[str, Any]]] = None,
               priorities: Optional[Sequence[int]] = None,
               ) -> List[RequestHandle]:
        """Submit prompts into the resident engine's persistent loop.

        Non-blocking: the requests join the engine's running decode batch
        (continuous batching); callers wait on the returned handles.
        ``priorities`` (per-prompt, default all-0) feed the engine's
        SLO-lane admission (DESIGN.md §10.3).
        """
        eng = self.engine_for(model)
        extras = extras or [{} for _ in prompts]
        prios = priorities or [0] * len(prompts)
        return [eng.submit(p, max_new_tokens=max_new_tokens,
                           temperature=temperature, extra=e, priority=pr)
                for p, e, pr in zip(prompts, extras, prios)]

    def engines(self) -> List[InferenceEngine]:
        """Snapshot of every engine ever created on this host."""
        with self._engines_lock:
            return list(self._engines.values())

    def shutdown(self) -> None:
        """Stop every engine's loop thread (stats stay readable)."""
        for eng in self.engines():
            eng.shutdown()


class GPUWorkerThread(threading.Thread):
    def __init__(self, wid: int, board: PlanBoard, graph: GraphSpec,
                 state: BatchState, bindings: Sequence[dict],
                 host: EngineHost, records: List[TaskRecord],
                 records_lock: threading.Lock, t0: float,
                 die_after: Optional[int] = None, pipelining: bool = True,
                 optimizer=None, migrator=None,
                 claim_ahead: Optional[int] = None,
                 stop_event: Optional[threading.Event] = None,
                 faults: Optional[FaultInjector] = None):
        super().__init__(daemon=True, name=f"gpu{wid}")
        self.wid = wid
        self.board = board
        self.graph = graph                              # swap-only
        self.state = state
        self.bindings = bindings
        self.host = host
        self.records = records              # guarded-by: self.records_lock
        self.records_lock = records_lock    # lock-alias: ProcessorSession._rlock
        self.t0 = t0
        self.die_after = die_after
        self.pipelining = pipelining
        self.optimizer = optimizer
        self.migrator = migrator
        self.faults = faults
        # claim throttling: claim at most this many not-yet-completed
        # nodes ahead (None = unlimited).  Pipelined submission races
        # claims far ahead of completions, collapsing the replanning
        # window to nothing; a small K keeps late-batch drift replans
        # able to re-place real work.
        self.claim_ahead = claim_ahead
        # session mode: when set, the worker parks on an empty board
        # (never exits on exhaustion — a graft may hand it new work) and
        # only returns once the event fires (DESIGN.md §10.1)
        self.stop_event = stop_event
        self.executed = 0                               # guarded-by: gpu-worker
        self.error: Optional[BaseException] = None      # swap-only
        self._outstanding: List[RequestHandle] = []     # guarded-by: gpu-worker
        self._my_claims: List[str] = []                 # guarded-by: gpu-worker

    # runs-on: any
    def rebind(self, graph: GraphSpec) -> None:
        """Adopt a grafted supergraph (atomic reference swap; node specs
        already claimed are identical in the new graph)."""
        self.graph = graph

    # ------------------------------------------------------------------
    # runs-on: any
    def _fail(self, err: BaseException) -> None:
        if self.error is None:
            self.error = err
        with self.state.lock:
            self.state.lock.notify_all()

    def _pending_queries(self, nid: str) -> List[int]:
        with self.state.lock:
            return [q for q in self.state.queries_for(nid)
                    if (q, nid) not in self.state.results]

    # ----------------------------------------------------- barrier mode
    def _run_node_barrier(self, nid: str) -> None:
        spec = self.graph.nodes[nid]
        if self.state.is_macro_done(nid):
            return                                   # restored from checkpoint
        # the board releases claims on parents-CLAIMED, so this wait is
        # real in barrier mode — give it the same 600s budget as every
        # other dependency wait
        self.state.wait_macro_ready(nid, timeout=600.0)
        queries = self.state.queries_for(nid)   # this node's template slice
        if not queries:
            return
        eng = self.host.engine_for(spec.model)
        prompts = []
        for q in queries:
            text = render(spec.prompt, self.bindings[q],
                          self.state.upstream(q))
            prompts.append(tokenize(text, eng.cfg.vocab_size))
        self.host.log_prompts(nid, prompts)
        ts = time.perf_counter() - self.t0
        handles = self.host.submit(
            spec.model, prompts, max_new_tokens=spec.max_new_tokens,
            temperature=spec.temperature,
            priorities=[self.state.priority_of(q) for q in queries])
        outs = [h.result() for h in handles]
        te = time.perf_counter() - self.t0
        with self.records_lock:
            self.records.append(TaskRecord(
                node=nid, kind="llm", worker=f"gpu{self.wid}",
                start=ts, end=te, batch=len(prompts)))
        if self.optimizer is not None:
            self.optimizer.observe_llm(nid, len(prompts), te - ts,
                                       f"gpu{self.wid}", span=(ts, te))
        for q, toks in zip(queries, outs):
            self.state.set_result(q, nid, detokenize(toks))

    # --------------------------------------------------- pipelined mode
    def _run_node_pipelined(self, nid: str) -> None:
        """Submit ``nid``'s per-query requests as each query's deps land;
        publish each result from the handle's completion callback.

        Returns once every query is SUBMITTED (not completed): the worker
        moves on to its next node while this one is still decoding, so
        same-model successors join the running continuous batch.
        """
        spec = self.graph.nodes[nid]
        state = self.state
        todo = self._pending_queries(nid)        # checkpoint-restored skipped
        if not todo:
            return
        node_track = {"done": 0, "expected": len(todo)}
        tlock = threading.Lock()
        eng = None
        pending = set(todo)
        deadline = time.monotonic() + 600.0
        while pending:
            if self.stop_event is not None and self.stop_event.is_set():
                return                       # session closing mid-node
            if time.monotonic() > deadline:
                raise TimeoutError(f"deps of {nid!r} never completed")
            wave = self._settle_ready_wave(nid, pending)
            if not wave:
                with state.lock:
                    state.lock.wait(timeout=0.05)
                continue
            if eng is None:
                # first ready query pays the (measured) model switch
                eng = self.host.engine_for(spec.model)
            # one TaskRecord per submission wave: a wave's span is real
            # engine-busy time, whereas one node-wide record would count
            # the gaps spent waiting for later queries' deps as GPU work
            # (inflating overlap and poisoning calibration samples)
            wave_track = {"done": 0, "expected": len(wave),
                          "start": time.perf_counter() - self.t0}
            wave_prompts = []
            for q in wave:
                text = render(spec.prompt, self.bindings[q],
                              state.upstream(q))
                toks = tokenize(text, eng.cfg.vocab_size)
                wave_prompts.append(toks)
                h = eng.submit(toks,
                               max_new_tokens=spec.max_new_tokens,
                               temperature=spec.temperature,
                               priority=state.priority_of(q))
                h.add_done_callback(
                    self._on_request_done(nid, q, node_track, wave_track,
                                          tlock))
                self._outstanding.append(h)
                pending.discard(q)
            self.host.log_prompts(nid, wave_prompts)

    def _settle_ready_wave(self, nid: str, pending: set) -> List[int]:
        """Queries of ``nid`` ready right now, after a short settle loop.

        Same-decode-step completions upstream land microseconds apart;
        without settling they would trickle into the engine one by one
        and fragment the partial batch (and, on the JIT path, recompile
        per batch shape).  Bounded at ~20 ms — still far finer-grained
        than the macro barrier it replaces.  When the engines run their
        own grace-window admission (``admission_window`` engine kwarg),
        the window subsumes this loop and the wave submits immediately.
        """
        ready = {q for q in pending if self.state.query_ready(q, nid)}
        if not ready:
            return []
        if self.host.engine_kwargs.get("admission_window", 0) > 0:
            return sorted(ready)         # engine-side window batches these
        for _ in range(10):
            time.sleep(0.002)
            grown = {q for q in pending if self.state.query_ready(q, nid)}
            if grown == ready:
                break
            ready = grown
        return sorted(ready)

    # runs-on: any
    def _on_request_done(self, nid: str, q: int, node_track: dict,
                         wave_track: dict, tlock: threading.Lock):
        """Per-handle callback: publish this query's result immediately
        (its tool tasks wake without waiting on batch stragglers)."""
        def _cb(h: RequestHandle) -> None:
            try:
                self._publish(h, nid, q, node_track, wave_track, tlock)
            except BaseException as e:     # engine swallows callback raises
                self._fail(e)
        return _cb

    # runs-on: any
    def _publish(self, h: RequestHandle, nid: str, q: int,
                 node_track: dict, wave_track: dict,
                 tlock: threading.Lock) -> None:
        err = h.exception()
        if err is not None:
            self._fail(err)
            return
        toks = h.result(timeout=1.0)
        te = time.perf_counter() - self.t0
        with tlock:
            wave_track["done"] += 1
            node_track["done"] += 1
            wave_done = wave_track["done"] == wave_track["expected"]
            node_done = node_track["done"] == node_track["expected"]
        if wave_done:                     # record before the final publish
            ts = wave_track["start"]
            with self.records_lock:
                self.records.append(TaskRecord(
                    node=nid, kind="llm", worker=f"gpu{self.wid}",
                    start=ts, end=te, batch=wave_track["expected"]))
            if self.optimizer is not None:
                self.optimizer.observe_llm(
                    nid, wave_track["expected"], te - ts,
                    f"gpu{self.wid}", node_complete=node_done,
                    span=(ts, te))
        self.state.set_result(q, nid, detokenize(toks))

    # ------------------------------------------------------------------
    def _drain_outstanding(self) -> None:
        for h in self._outstanding:
            try:
                h.result(timeout=600)
            except BaseException as e:
                if self.error is None:
                    self.error = e
        self._outstanding.clear()

    def _claims_in_flight(self) -> int:
        """My claimed nodes whose macro result has not landed yet."""
        with self.state.lock:
            return sum(1 for n in self._my_claims
                       if n not in self.state.macro_done)

    def _finished(self) -> bool:
        """One-shot mode ends with the batch; session mode (stop_event
        set) parks through exhaustion and ends only on the event."""
        if self.stop_event is not None:
            return self.stop_event.is_set()
        return self.state.all_done()

    # runs-on: gpu-worker
    def run(self) -> None:
        """Claim nodes off the board until nothing is left for us; pick
        up failed peers' overflow work the moment it is claimable.  In
        session mode an idle worker parks instead of exiting: a graft's
        splice (which notifies the board lock) can hand it new work at
        any time (DESIGN.md §10.1)."""
        try:
            while not self._finished():
                if (self.die_after is not None
                        and self.executed >= self.die_after):
                    self.board.abandon(self.wid)     # simulated failure
                    break
                if (self.claim_ahead is not None and self.error is None
                        and self._claims_in_flight() >= self.claim_ahead):
                    # throttle: wait for one of our claimed nodes to
                    # complete before taking the next (already-claimed
                    # work keeps decoding — only NEW claims wait)
                    with self.state.lock:
                        self.state.lock.wait(timeout=0.05)
                    continue
                nid = self.board.try_claim(self.wid)
                if nid is None:
                    if self.stop_event is None and \
                            self.board.exhausted(self.wid):
                        break                        # nothing left for us
                    with self.board.lock:
                        self.board.lock.wait(timeout=0.05)
                    continue
                self._my_claims.append(nid)
                if self.faults is not None:
                    # injected slowdown: stall before submitting so the
                    # perturbation shifts real decode/claim ordering
                    delay = self.faults.engine_delay(self.wid, nid)
                    if delay > 0.0:
                        time.sleep(delay)
                if self.migrator is not None:
                    # claim-time KV pull: warm lineage on a peer worker
                    # (parent ran there, or a prior micro-batch did)
                    # lands here before this node's first wave submits
                    self.migrator.migrate_node_from_peers(nid, self.wid)
                if self.pipelining:
                    self._run_node_pipelined(nid)
                else:
                    self._run_node_barrier(nid)
                self.executed += 1
            self._drain_outstanding()
        except BaseException as e:                    # surfaced by Processor
            self._fail(e)


class ToolDispatcher(threading.Thread):
    """Promotes per-query tool tasks as their deps land; coalesces by
    canonical signature; executes on a bounded pool (backpressure).

    Event-driven: a BatchState listener feeds every landed (query, node)
    result into a queue; each event only wakes the *children* tool tasks
    of that result (incremental scan) instead of re-walking the whole
    O(nodes × queries) grid on a timer.
    """

    _FULL_SCAN_EVERY = 40          # safety-net sweeps (~10 s apart)

    def __init__(self, graph: GraphSpec, state: BatchState,
                 bindings: Sequence[dict], tools: ToolRuntime,
                 records: List[TaskRecord], records_lock: threading.Lock,
                 t0: float, cpu_slots: int = 8, coalescing: bool = True,
                 optimizer=None, persistent: bool = False,
                 faults: Optional[FaultInjector] = None,
                 tool_retries: int = 2):
        super().__init__(daemon=True, name="tool-dispatcher")
        self.graph = graph                              # swap-only
        # session mode: outlive batch completion (a graft may add work);
        # the owner is responsible for stop()
        self.persistent = persistent
        self._force_scan = threading.Event()
        self.state = state
        self.bindings = bindings
        self.tools = tools
        self.records = records              # guarded-by: self.records_lock
        self.records_lock = records_lock    # lock-alias: ProcessorSession._rlock
        self.t0 = t0
        self.optimizer = optimizer
        self.faults = faults
        self.tool_retries = max(int(tool_retries), 0)
        self.pool = ThreadPoolExecutor(max_workers=cpu_slots)
        self.table = CoalesceTable(enabled=coalescing)
        self.dispatched: set = set()            # guarded-by: tool-dispatcher
        self.stop_flag = threading.Event()
        self.error: Optional[BaseException] = None      # swap-only
        self._retry_lock = named_lock("ToolDispatcher._retry_lock")
        self.retries_used = 0                   # guarded-by: self._retry_lock
        self._events: "_q.SimpleQueue" = _q.SimpleQueue()
        self._wake = threading.Event()
        self._depth = {t: len(graph.ancestors(t))       # swap-only
                       for t in graph.tool_nodes()}
        self._tool_children = {                         # swap-only
            nid: [c for c in graph.children(nid)
                  if not graph.nodes[c].is_llm()]
            for nid in graph.nodes}
        state.add_listener(self._on_result)

    # ------------------------------------------------------------------
    # runs-on: any
    def _on_result(self, q: int, node: str) -> None:
        """BatchState listener — runs on the producing thread; enqueue
        and wake only (no dispatch work here)."""
        self._events.put((q, node))
        self._wake.set()

    def stop(self) -> None:
        self.stop_flag.set()
        self._wake.set()

    # runs-on: any
    def rebind(self, graph: GraphSpec) -> None:
        """Adopt a grafted supergraph and force a full dispatch sweep.

        Grafted ROOT tool nodes have no upstream result to trigger the
        incremental event path, so the next loop iteration runs a full
        ``_scan`` over the (grown) shared-identity bindings list.  The
        derived indices are rebuilt before the graph swap publishes."""
        depth = {t: len(graph.ancestors(t)) for t in graph.tool_nodes()}
        children = {nid: [c for c in graph.children(nid)
                          if not graph.nodes[c].is_llm()]
                    for nid in graph.nodes}
        self._depth = depth
        self._tool_children = children
        self.graph = graph
        self._force_scan.set()
        self._wake.set()

    # ------------------------------------------------------------------
    # runs-on: cpu-pool
    def _execute(self, sig: str, op: str, args: str, origin: str,
                 attempt: int = 1) -> None:
        try:
            ts = time.perf_counter() - self.t0
            if self.faults is not None:
                self.faults.tool_call(sig, op)
            result, _ = self.tools.execute(op, args)
            te = time.perf_counter() - self.t0
        except TransientToolError as e:
            # bounded retry: transient (injected or real network-blip
            # style) failures re-enter the pool instead of killing the
            # run; only exhaustion surfaces as a session error
            if attempt <= self.tool_retries and \
                    not self.stop_flag.is_set():
                with self._retry_lock:
                    self.retries_used += 1
                try:
                    self.pool.submit(self._execute, sig, op, args, origin,
                                     attempt + 1)
                    return
                except RuntimeError:
                    # pool shut down between the stop_flag check and the
                    # resubmit: fall through so the failure surfaces as
                    # the session error and waiters wake instead of
                    # timing out on a result that will never land
                    pass
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()
            return
        except BaseException as e:          # non-transient: fail the run
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()
            return
        try:
            with self.state.lock:
                requesters = self.table.complete(sig, result)
            with self.records_lock:
                # ``origin`` keeps the record attributable even when a
                # coalesced signature completes with no live requesters
                self.records.append(TaskRecord(
                    node=origin, kind="tool", worker="cpu", start=ts,
                    end=te, batch=max(len(requesters), 1), info=op))
            if self.optimizer is not None:
                self.optimizer.observe_tool(origin, op, te - ts)
            for q, nid in requesters:
                self.state.set_result(q, nid, str(result))
        except BaseException as e:
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()

    def _maybe_dispatch(self, q: int, nid: str) -> bool:
        """Dispatch one (query, tool) task if ready. Returns True if it
        was dispatched (or served from the coalesce cache) just now."""
        key = (q, nid)
        if key in self.dispatched or not self.state.serves(q, nid):
            return False
        with self.state.lock:
            if key in self.state.results:
                self.dispatched.add(key)                 # checkpointed
                return False
        if not self.state.query_ready(q, nid):
            return False
        self.dispatched.add(key)
        spec = self.graph.nodes[nid]
        args = render(spec.args, self.bindings[q], self.state.upstream(q))
        with self.state.lock:
            sig, needs_exec, cached = self.table.register(
                spec.op, args, (q, nid))
        if cached is not None:
            self.state.set_result(q, nid, str(cached))
        elif needs_exec:
            self.pool.submit(self._execute, sig, spec.op, args, nid)
        return True

    def _scan(self) -> int:
        """Full sweep: dispatch every ready (query, tool) task.  Used at
        startup (roots + checkpoint-restored deps) and as a low-frequency
        safety net; steady-state promotion is event-driven."""
        n = 0
        tool_nodes = sorted(self.graph.tool_nodes(),
                            key=lambda t: self._depth[t])    # depth priority
        for nid in tool_nodes:
            for q in self.state.queries_for(nid):
                if self._maybe_dispatch(q, nid):
                    n += 1
        return n

    def _drain_events(self) -> int:
        """Incremental promotion: only the tool children of freshly
        landed results are candidates."""
        batch = []
        try:
            while True:
                batch.append(self._events.get_nowait())
        except _q.Empty:
            pass
        cand = {(q, c) for q, node in batch
                for c in self._tool_children.get(node, ())}
        n = 0
        for q, nid in sorted(cand,
                             key=lambda t: (self._depth[t[1]], t[0], t[1])):
            if self._maybe_dispatch(q, nid):
                n += 1
        return n

    # runs-on: tool-dispatcher
    def run(self) -> None:
        try:
            self._scan()
            idle = 0
            while not self.stop_flag.is_set() and \
                    (self.persistent or not self.state.all_done()):
                if self._wake.wait(timeout=0.25):
                    self._wake.clear()
                    idle = 0
                else:
                    idle += 1
                self._drain_events()
                if self._force_scan.is_set():        # a graft landed
                    self._force_scan.clear()
                    idle = 0
                    self._scan()
                if idle >= self._FULL_SCAN_EVERY:
                    idle = 0
                    self._scan()
        except BaseException as e:
            self.error = e
            with self.state.lock:
                self.state.lock.notify_all()
        finally:
            self.pool.shutdown(wait=True)
