"""Discrete-event cluster simulator — the paper-scale Processor backend.

The SAME planning code (consolidator, cost model, DP solver, baseline
schedulers) drives both this simulator and the real backend; only task
execution is simulated, with latencies from the calibrated cost model.
This is how the paper's H200-scale numbers (N=1024, 14B–32B models) are
reproduced on a CPU-only container (DESIGN.md §6).

Faithful §5 mechanics:
* wavefront execution without epoch barriers (workers run their planned
  sequence, waiting only on true data deps);
* depth-priority CPU scheduling (tools unlocking the nearest LLM first);
* bounded CPU pool with backpressure (slot-based);
* request coalescing at signature level, INCLUDING cross-instance reuse
  in online mode (the cross-session batching Table 2 credits Halo);
* opportunistic execution: an idle worker pulls a later ready node only
  if it does not disturb imminent model residency;
* deterministic straggler jitter on HTTP tools (tail latency masking);
* worker-failure injection + plan redistribution (fault tolerance).
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.consolidate import ConsolidatedGraph
from repro.core.cost_model import CostModel
from repro.core.graphspec import GraphSpec
from repro.core.plan import ExecutionPlan
from repro.core.state import WorkerContext
from repro.runtime.events import RunReport, TaskRecord

Key = Tuple[int, str]                      # (instance, node_id)


@dataclass
class _Instance:
    cons: ConsolidatedGraph
    plan: ExecutionPlan
    arrival: float
    done: Set[str] = field(default_factory=set)
    finished_at: float = -1.0


class ClusterSimulator:
    def __init__(self, graph: GraphSpec, cost_model: CostModel,
                 num_workers: int, cpu_slots: int = 16,
                 coalescing: bool = True, opportunistic: bool = True,
                 cross_instance_cache: bool = True,
                 lookahead: int = 24, seed: int = 0,
                 llm_jitter: float = 0.05,
                 barrier_mode: bool = False,
                 processor_batch: int = 256,
                 kv_migration: bool = True):
        self.graph = graph
        self.cm = cost_model
        self.W = num_workers
        self.cpu_slots = cpu_slots
        self.coalescing = coalescing
        self.opportunistic = opportunistic
        self.cross_instance_cache = cross_instance_cache and coalescing
        self.lookahead = lookahead
        self.seed = seed
        self.llm_jitter = llm_jitter
        # Halo's Processor migrates warm KV across workers (§5), so the
        # peer-context prefill credit the solver priced is REALIZED at
        # execution; baseline systems (langgraph/agentscope/parrot/
        # vllm-serial) do not migrate and run with this off.
        self.kv_migration = kv_migration
        # Strict stage barriers — a worker may not start an epoch-e node
        # until EVERY node of epochs < e (same instance) completed.  Used
        # for the OpWise baseline AND for the "w/o opportunistic" ablation
        # (§6.5: without it the Processor is bound to the scheduler's
        # static dispatch rate).  Halo itself runs barrier-free wavefronts.
        self.barrier_mode = barrier_mode
        # engine max batch per forward wave (Fig. 10 sensitivity)
        self.processor_batch = processor_batch

        self.instances: List[_Instance] = []
        self._failures: List[Tuple[float, int]] = []

        # static tool depth priority: hops to the nearest LLM descendant
        self._tool_depth: Dict[str, int] = {}
        for t in graph.tool_nodes():
            depth, frontier, seen = 0, [t], {t}
            found = 99
            while frontier and found == 99:
                nxt = []
                for x in frontier:
                    for c in graph.children(x):
                        if graph.nodes[c].is_llm():
                            found = depth
                        elif c not in seen:
                            seen.add(c)
                            nxt.append(c)
                frontier, depth = nxt, depth + 1
            self._tool_depth[t] = found

    # ------------------------------------------------------------------
    def add_instance(self, cons: ConsolidatedGraph, plan: ExecutionPlan,
                     arrival: float = 0.0) -> int:
        self.instances.append(_Instance(cons, plan, arrival))
        return len(self.instances) - 1

    def add_failure(self, time: float, worker: int) -> None:
        self._failures.append((time, worker))

    # ------------------------------------------------------------------
    def _n_phys(self, inst: _Instance, nid: str,
                global_sigs: Set[str]) -> Tuple[int, int]:
        """(logical, physical) request counts for a macro node.

        LLM calls are NEVER deduped (paper semantics: coalescing merges
        redundant I/O/tool operations; every query's LLM call runs —
        continuous batching amortizes them instead)."""
        m = inst.cons.macro(nid)
        if self.graph.nodes[nid].is_llm() or not self.coalescing:
            return m.n_logical, m.n_logical
        # physical_signatures already removes cross-TEMPLATE duplicates a
        # multi-template mega-DAG coalesced; the global set removes
        # cross-INSTANCE duplicates on top
        sigs = inst.cons.physical_signatures(nid)
        if self.cross_instance_cache:
            fresh = [s for s in sigs if s not in global_sigs]
            return m.n_logical, max(len(fresh), 0)
        return m.n_logical, len(sigs)

    def _rng(self, *key) -> random.Random:
        return random.Random(hash((self.seed,) + key) & 0x7FFFFFFF)

    def _tool_duration(self, inst: _Instance, nid: str, n_phys: int,
                       slots: int) -> float:
        spec = self.graph.nodes[nid]
        est = self.cm.profiler.estimate(spec)
        if n_phys == 0:
            return 1e-4                         # pure cache hit: bookkeeping
        waves = math.ceil(n_phys / max(slots, 1))
        dur = est * waves
        if spec.op == "http":                   # deterministic straggler tail
            r = self._rng("http", inst.arrival, nid).random()
            dur *= 3.0 if r < 0.10 else 1.0 + 0.3 * r
        return dur

    def _llm_duration(self, inst: _Instance, nid: str, n_phys: int,
                      ctx: WorkerContext,
                      peers: Tuple[WorkerContext, ...] = ()
                      ) -> Tuple[float, WorkerContext]:
        spec = self.graph.nodes[nid]
        llm_parents = [p for p in self.graph.parents(nid)
                       if self.graph.nodes[p].is_llm()]
        old = self.cm.batch_sizes.get(nid)
        # engine processes the macro batch in waves of processor_batch
        t = self.cm.t_model(spec, ctx)
        remaining = max(n_phys, 1)
        first = True
        while remaining > 0:
            wave = min(remaining, self.processor_batch)
            self.cm.batch_sizes[nid] = wave
            t += self.cm.t_infer(spec, ctx, llm_parents, peer_ctxs=peers)
            if not first and peers and self.cm.use_profiling:
                # ONE transfer serves every wave (the imported pages are
                # local after the first) — refund the repeated t_mig term
                t -= self.cm.prefill_plan(spec, ctx, llm_parents, peers)[1]
            first = False
            remaining -= wave
        if old is None:
            self.cm.batch_sizes.pop(nid, None)
        else:
            self.cm.batch_sizes[nid] = old
        r = self._rng("llm", inst.arrival, nid).random()
        t *= 1.0 + self.llm_jitter * r
        return t, ctx.after(nid, spec.model)

    # ------------------------------------------------------------------
    def run(self) -> RunReport:
        report = RunReport(num_workers=self.W)
        heap: List[Tuple[float, int, str, tuple]] = []
        counter = 0

        def push(t, kind, payload):
            nonlocal counter
            heapq.heappush(heap, (t, counter, kind, payload))
            counter += 1

        # per-worker state
        queue: List[List[Key]] = [[] for _ in range(self.W)]
        ptr: List[int] = [0] * self.W
        ctxs: List[WorkerContext] = [WorkerContext() for _ in range(self.W)]
        busy: List[bool] = [False] * self.W
        dead: List[bool] = [False] * self.W
        executed: Set[Key] = set()          # done or in-flight LLM nodes
        inflight: Dict[int, Key] = {}       # worker -> running node

        done: Set[Key] = set()
        free_slots = [self.cpu_slots]
        tool_ready: List[Tuple[int, float, int, str]] = []   # priority heap
        tool_inflight: Set[Key] = set()
        global_sigs: Set[str] = set()

        for t, w in self._failures:
            push(t, "fail", (w,))
        for i, inst in enumerate(self.instances):
            push(inst.arrival, "arrive", (i,))

        # epoch index per (instance, node) for barrier mode; tool nodes are
        # gated on the stage boundary before their earliest LLM child
        # (OpWise cannot interleave CPU tools with earlier GPU stages).
        epoch_of: Dict[Key, int] = {}
        epoch_nodes: Dict[Tuple[int, int], Set[str]] = {}
        if self.barrier_mode:
            for i, inst in enumerate(self.instances):
                for e_ix, ep in enumerate(inst.plan.epochs):
                    for comp in ep.components:
                        for v in comp:
                            epoch_of[(i, v)] = e_ix
                            epoch_nodes.setdefault((i, e_ix), set()).add(v)
                for tnode in self.graph.tool_nodes():
                    gates = [epoch_of[(i, c)] for c in self.graph.children(tnode)
                             if (i, c) in epoch_of]
                    if gates:
                        epoch_of[(i, tnode)] = min(gates)

        # ----------------------------------------------------------------
        def deps_done(i: int, v: str) -> bool:
            if not all((i, p) in done for p in self.graph.parents(v)):
                return False
            if self.barrier_mode and (i, v) in epoch_of:
                e_ix = epoch_of[(i, v)]
                for e_prev in range(e_ix):
                    if not all((i, u) in done
                               for u in epoch_nodes.get((i, e_prev), ())):
                        return False
            return True

        def promote_tools(t: float, i: int) -> None:
            """Queue newly-ready tool nodes (depth priority)."""
            inst = self.instances[i]
            for v in self.graph.tool_nodes():
                k = (i, v)
                if k in done or k in tool_inflight:
                    continue
                if deps_done(i, v):
                    tool_inflight.add(k)
                    heapq.heappush(tool_ready,
                                   (self._tool_depth[v], inst.arrival, i, v))

        def start_tools(t: float) -> None:
            while tool_ready and free_slots[0] > 0:
                _, _, i, v = heapq.heappop(tool_ready)
                inst = self.instances[i]
                n_log, n_phys = self._n_phys(inst, v, global_sigs)
                grab = max(min(n_phys, free_slots[0]), 1)
                free_slots[0] -= grab
                dur = self._tool_duration(inst, v, n_phys, grab)
                push(t + dur, "tool_done", (i, v, grab, n_log, n_phys, t))

        def try_start_worker(w: int, t: float, force: bool = False) -> None:
            if busy[w] or dead[w]:
                return
            q = queue[w]
            while ptr[w] < len(q) and q[ptr[w]] in executed:
                ptr[w] += 1
            if ptr[w] >= len(q):
                return
            # planned next node
            cand: Optional[Key] = None
            i0, v0 = q[ptr[w]]
            if deps_done(i0, v0):
                cand = (i0, v0)
            elif self.opportunistic or force:
                end = len(q) if force \
                    else min(len(q), ptr[w] + 1 + self.lookahead)
                for j in range(ptr[w] + 1, end):
                    i1, v1 = q[j]
                    if q[j] in executed or not deps_done(i1, v1):
                        continue
                    model = self.graph.nodes[v1].model
                    # do not disturb imminent GPU state (unless forced:
                    # the cluster would otherwise stall entirely)
                    if not force and ctxs[w].model and model != ctxs[w].model:
                        continue
                    cand = q[j]
                    break
            if cand is None:
                return
            i, v = cand
            inst = self.instances[i]
            n_log, n_phys = self._n_phys(inst, v, set())
            if n_log == 0:
                # empty template slice in a mega-DAG instance: nothing
                # to infer — retire instantly WITHOUT touching the
                # worker context (no phantom batch-1 wave or model
                # switch poisoning the consolidated-multi arm)
                busy[w] = True
                executed.add(cand)
                inflight[w] = cand
                push(t + 1e-4, "llm_done", (w, i, v, 0, t))
                return
            peers = tuple(ctxs[x] for x in range(self.W)
                          if x != w and not dead[x]) \
                if self.kv_migration else ()
            dur, nctx = self._llm_duration(inst, v, n_phys, ctxs[w], peers)
            ctxs[w] = nctx
            busy[w] = True
            executed.add(cand)
            inflight[w] = cand
            push(t + dur, "llm_done", (w, i, v, n_phys, t))

        def on_node_done(i: int, v: str, t: float) -> None:
            done.add((i, v))
            inst = self.instances[i]
            inst.done.add(v)
            if len(inst.done) == len(self.graph.nodes):
                inst.finished_at = t
                for _ in range(inst.cons.n_queries):
                    report.query_completion.append(t - 0.0)
            promote_tools(t, i)

        # ----------------------------------------------------------------
        t = 0.0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if kind == "arrive":
                (i,) = payload
                seqs = self.instances[i].plan.worker_sequences(self.W)
                alive = [w for w in range(self.W) if not dead[w]]
                # rotate worker assignment per instance: intra-instance
                # locality chains are preserved while concurrent instances
                # spread across the pool (cross-session load balancing)
                for w in range(self.W):
                    tgt = (w + i) % self.W
                    if dead[tgt]:
                        tgt = alive[tgt % len(alive)]
                    queue[tgt].extend((i, v) for v in seqs[w])
                promote_tools(t, i)
            elif kind == "tool_done":
                i, v, grab, n_log, n_phys, t0 = payload
                free_slots[0] += grab
                tool_inflight.discard((i, v))
                if self.cross_instance_cache:
                    global_sigs.update(
                        self.instances[i].cons.macro(v).unique_signatures)
                report.records.append(TaskRecord(
                    node=v, kind="tool", worker="cpu", start=t0, end=t,
                    batch=n_phys, instance=i,
                    info=f"logical={n_log}"))
                # online calibration with the PER-CALL latency
                waves = max(math.ceil(n_phys / max(grab, 1)), 1)
                self.cm.profiler.update(v, self.graph.nodes[v].op,
                                        ((t - t0) / waves) or 1e-4)
                on_node_done(i, v, t)
            elif kind == "llm_done":
                w, i, v, n_phys, t0 = payload
                if inflight.get(w) != (i, v):
                    continue                     # stale (worker failed)
                busy[w] = False
                del inflight[w]
                report.records.append(TaskRecord(
                    node=v, kind="llm", worker=f"gpu{w}", start=t0, end=t,
                    batch=n_phys, instance=i))
                on_node_done(i, v, t)
            elif kind == "fail":
                (w,) = payload
                if dead[w]:
                    continue
                dead[w] = True
                # reassign in-flight + remaining queue to survivors
                alive = [x for x in range(self.W) if not dead[x]]
                if not alive:
                    raise RuntimeError("all workers failed")
                moved: List[Key] = []
                if w in inflight:
                    k = inflight.pop(w)
                    executed.discard(k)
                    moved.append(k)
                    busy[w] = False
                moved += [k for k in queue[w][ptr[w]:] if k not in executed]
                queue[w] = []
                for j, k in enumerate(moved):
                    queue[alive[j % len(alive)]].append(k)
                report.extra[f"failed_worker_{w}"] = t

            # wake everything that can proceed
            start_tools(t)
            for w in range(self.W):
                try_start_worker(w, t)
            if not heap:
                # stall-breaker: nothing in flight and nothing started —
                # a failure redistribution can park a ready node behind a
                # dep-blocked head on a worker whose residency guard then
                # refuses every cross-model pull (every OTHER worker being
                # blocked on that node's output).  Rather than silently
                # dropping the tail of the batch, let stalled workers take
                # ANY dep-ready queued node, residency notwithstanding.
                for w in range(self.W):
                    try_start_worker(w, t, force=True)

        report.makespan = t
        report.num_queries = sum(i.cons.n_queries for i in self.instances)
        log = phys = 0
        for r in report.records:
            if r.kind == "tool":
                log += int(r.info.split("=")[1])
                phys += r.batch
        report.coalesce_stats = {
            "tool_logical": log, "tool_physical": phys,
            "tool_dedup_ratio": phys / max(log, 1),
        }
        return report


# ---------------------------------------------------------------------------
# convenience wrappers
# ---------------------------------------------------------------------------

class SimulatedProcessor:
    """One consolidated batch → one simulated run."""

    def __init__(self, graph: GraphSpec, cost_model: CostModel,
                 num_workers: int, **kw):
        self.sim = ClusterSimulator(graph, cost_model, num_workers, **kw)

    def run(self, cons: ConsolidatedGraph, plan: ExecutionPlan) -> RunReport:
        self.sim.add_instance(cons, plan, arrival=0.0)
        report = self.sim.run()
        report.name = plan.scheduler_name
        return report


class OnlineSimulator:
    """Streaming arrivals → micro-batches → overlapping plan instances."""

    def __init__(self, graph: GraphSpec, cost_model: CostModel,
                 num_workers: int, **kw):
        self.graph = graph
        self.cm = cost_model
        self.W = num_workers
        self.kw = kw

    def run(self, batches: Sequence[Tuple[ConsolidatedGraph, ExecutionPlan]],
            arrival_rate_qps: float) -> RunReport:
        sim = ClusterSimulator(self.graph, self.cm, self.W, **self.kw)
        t = 0.0
        for cons, plan in batches:
            sim.add_instance(cons, plan, arrival=t)
            t += cons.n_queries / arrival_rate_qps
        report = sim.run()
        report.name = "online"
        return report
