"""Deterministic fault injection for the runtime (DESIGN.md §12.3).

A ``FaultPlan`` names WHERE failures may strike (tool calls, worker
loss, engine slowdown) and a seed; a ``FaultInjector`` turns the plan
into deterministic per-site decisions — the roll for a given
(seed, site, key) is a pure hash, so two runs with the same plan
inject the *same* faults at the *same* points regardless of thread
interleaving.  That determinism is what makes chaos tests assertable:
a seeded run either recovers bitwise-identically or the regression is
real.

Three injection sites, all riding existing recovery machinery:

* ``tool_call`` — raises ``TransientToolError`` for the first
  ``max_tool_failures`` attempts of an unlucky signature; the
  ``ToolDispatcher`` retries (``tool_retries``), so any plan with
  ``tool_retries > max_tool_failures`` is guaranteed to complete.
* ``kill_worker`` — maps worker id → executed-node count after which
  the worker abandons (``PlanBoard.abandon``); surviving workers pick
  up the overflow exactly as they would a real thread death.
* ``engine_delay`` — seconds of sleep before an unlucky (worker,
  node) submission, perturbing timing to shake out ordering races and
  (with an optimizer attached) trigger drift replans.

``FaultPlan.from_env`` reads the ``REPRO_FAULT_*`` variables so the CI
chaos matrix is just an env sweep.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional

from repro.debugsync import named_lock


class TransientToolError(RuntimeError):
    """An injected, retryable tool failure (network blip stand-in)."""


def _parse_kill(spec: str) -> Dict[int, int]:
    """``"0:1,2:3"`` → {worker 0 dies after 1 node, worker 2 after 3}."""
    out: Dict[int, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            wid, after = part.split(":")
            out[int(wid)] = int(after)
        except ValueError:
            raise ValueError(
                f"bad REPRO_FAULT_KILL entry {part!r}; expected "
                "'wid:after' pairs like '0:1,2:3'") from None
    return out


@dataclass(frozen=True)
class FaultPlan:
    """What may fail and how often (all decisions derive from ``seed``)."""

    seed: int = 0
    # probability an eligible tool-call attempt raises TransientToolError
    tool_fail_rate: float = 0.0
    # an unlucky signature fails at most this many attempts, so retries
    # beyond it always succeed (bounds injected failures per site)
    max_tool_failures: int = 1
    # worker id -> executed-node count after which it abandons
    kill_worker: Dict[int, int] = field(default_factory=dict)
    # seconds of pre-submission delay for unlucky (worker, node) pairs
    engine_delay_s: float = 0.0
    engine_delay_rate: float = 0.0

    @classmethod
    def from_env(cls, env: Optional[Mapping[str, str]] = None
                 ) -> Optional["FaultPlan"]:
        """Build a plan from ``REPRO_FAULT_*`` variables; None when
        ``REPRO_FAULT_SEED`` is unset (fault injection off)."""
        env = os.environ if env is None else env
        seed = env.get("REPRO_FAULT_SEED")
        if seed is None:
            return None
        return cls(
            seed=int(seed),
            tool_fail_rate=float(env.get("REPRO_FAULT_TOOL_RATE", "0")),
            max_tool_failures=int(env.get("REPRO_FAULT_TOOL_MAX", "1")),
            kill_worker=_parse_kill(env.get("REPRO_FAULT_KILL", "")),
            engine_delay_s=float(env.get("REPRO_FAULT_DELAY_S", "0")),
            engine_delay_rate=float(env.get("REPRO_FAULT_DELAY_RATE", "0")),
        )


class FaultInjector:
    """Turns a ``FaultPlan`` into deterministic injection decisions."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = named_lock("FaultInjector._lock")
        # signature -> tool-call attempts seen so far
        self._attempts: Dict[str, int] = {}     # guarded-by: self._lock
        self.tool_faults = 0                    # guarded-by: self._lock
        self.delays_injected = 0                # guarded-by: self._lock

    def _roll(self, site: str, key: str) -> float:
        """Uniform [0, 1) from (seed, site, key) — pure, so every run
        with this plan rolls the same number at the same point."""
        payload = f"{self.plan.seed}|{site}|{key}".encode()
        digest = hashlib.blake2b(payload, digest_size=8).digest()
        return int.from_bytes(digest, "big") / 2.0 ** 64

    # ------------------------------------------------------------ sites
    def tool_call(self, sig: str, op: str) -> None:
        """Raise ``TransientToolError`` if this attempt of ``sig`` is
        unlucky.  Attempts beyond ``max_tool_failures`` always pass, so
        dispatcher retries are guaranteed to eventually succeed."""
        p = self.plan
        if p.tool_fail_rate <= 0.0:
            return
        with self._lock:
            attempt = self._attempts.get(sig, 0) + 1
            self._attempts[sig] = attempt
            if attempt > p.max_tool_failures:
                return
            if self._roll("tool", sig) >= p.tool_fail_rate:
                return
            self.tool_faults += 1
        raise TransientToolError(
            f"injected fault: {op} attempt {attempt} of {sig!r} "
            f"(seed {p.seed})")

    def engine_delay(self, wid: int, nid: str) -> float:
        """Seconds to stall worker ``wid`` before submitting ``nid``
        (0.0 when this pair is lucky)."""
        p = self.plan
        if p.engine_delay_s <= 0.0 or p.engine_delay_rate <= 0.0:
            return 0.0
        if self._roll("delay", f"{wid}|{nid}") >= p.engine_delay_rate:
            return 0.0
        with self._lock:
            self.delays_injected += 1
        return p.engine_delay_s

    def die_after(self, wid: int) -> Optional[int]:
        """Executed-node budget for ``wid`` (None = never dies)."""
        return self.plan.kill_worker.get(wid)

    # ---------------------------------------------------------- summary
    def summary(self) -> Dict[str, int]:
        with self._lock:
            return {"seed": self.plan.seed,
                    "tool_faults_injected": self.tool_faults,
                    "engine_delays_injected": self.delays_injected,
                    "workers_killed": len(self.plan.kill_worker)}
