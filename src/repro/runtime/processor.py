"""RealProcessor — executes an ExecutionPlan with REAL components
(DESIGN.md §7):

tiny JAX models behind InferenceEngines (continuous batching, prefix
sharing, model switching), the minidb ToolRuntime, signature coalescing,
per-query wavefront tool promotion, checkpoint/restart and worker-failure
recovery.  The scheduling logic is the SAME code the simulator drives —
real mode exists to prove the semantics: coalescing, plan choice,
per-request pipelining and mid-run replanning must not change outputs
(asserted in tests).

Per-request CPU-GPU pipelining is on by default: each query's result is
published the moment its request retires (releasing that query's tool
tasks immediately) and a node's per-query requests are submitted as soon
as that query's deps land — no macro barrier.  Pass an
``OnlineOptimizer`` to ``run`` to additionally calibrate the cost model
from measured latencies and re-solve the remaining DAG mid-run when
observed epoch cost drifts from the plan's predictions.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.consolidate import ConsolidatedGraph
from repro.core.graphspec import GraphSpec
from repro.core.plan import ExecutionPlan
from repro.runtime.checkpoint import load_batch_state, save_batch_state
from repro.runtime.coordinator import BatchState, PlanBoard
from repro.runtime.events import RunReport, TaskRecord
from repro.runtime.executors import (EngineHost, GPUWorkerThread,
                                     ToolDispatcher)
from repro.runtime.migrate import KVMigrator
from repro.workloads.tools import ToolRuntime

# engine counters that accumulate monotonically (reported as per-run
# deltas so persistent hosts don't leak prior runs into each report)
_ENGINE_COUNTERS = ("prefill_tokens_saved", "admission_waves",
                    "pages_shared", "tokens_reused", "coalesced_requests",
                    "pages_migrated_in", "pages_migrated_out",
                    "migrate_seconds", "h2d_bytes", "d2h_bytes",
                    "view_rebuilds")


class RealProcessor:
    def __init__(self, graph: GraphSpec, model_configs: Dict[str, ModelConfig],
                 tools: ToolRuntime, num_workers: int = 2,
                 cpu_slots: int = 8, coalescing: bool = True, seed: int = 0,
                 decode_cap: Optional[int] = None, pipelining: bool = True,
                 engine_kwargs: Optional[Dict[str, Any]] = None,
                 kv_migration: bool = True,
                 claim_ahead: Optional[int] = None):
        self.graph = graph
        self.model_configs = model_configs
        self.tools = tools
        self.W = num_workers
        self.cpu_slots = cpu_slots
        self.coalescing = coalescing
        self.seed = seed
        self.pipelining = pipelining
        self.engine_kwargs = engine_kwargs
        # migrate moved nodes' warm KV on plan splices (off = A/B control)
        self.kv_migration = kv_migration
        # workers claim at most this many incomplete nodes ahead (None =
        # unlimited) so pipelined claims can't outrun completions and
        # starve the mid-run replanning window
        self.claim_ahead = claim_ahead
        # cap generation length in tests (CPU real mode); None = node spec
        if decode_cap is not None:
            nodes = [n.with_(max_new_tokens=min(n.max_new_tokens, decode_cap))
                     if n.is_llm() else n for n in graph.nodes.values()]
            self.graph = GraphSpec(graph.name, nodes, graph.edges)

    # ------------------------------------------------------------------
    @staticmethod
    def _engine_totals(hosts: List[EngineHost]) -> Dict[str, int]:
        engines = [e for h in hosts for e in h._engines.values()]
        out = {k: sum(getattr(e.stats, k) for e in engines)
               for k in _ENGINE_COUNTERS}
        out["model_switches"] = sum(h.switches for h in hosts)
        return out

    # ------------------------------------------------------------------
    @staticmethod
    def _cross_template_stats(cons: ConsolidatedGraph,
                              table) -> Dict[str, int]:
        """Runtime cross-template coalescing: physical tool executions
        whose logical requesters span >= 2 templates (the merges only a
        multi-template mega-DAG makes possible)."""
        merged_tasks = 0
        merged_requests = 0
        tasks = list(table.completed.values()) + list(table.pending.values())
        for task in tasks:
            if not task.requesters:
                continue
            # only requesters from a DIFFERENT template than the one
            # whose request ran the physical execution count as
            # cross-template merges — same-template coalescing on a
            # spanning task is ordinary dedup, not a mega-DAG win
            owner = cons.template_of[task.requesters[0][1]]
            crossed = sum(1 for _, nid in task.requesters
                          if cons.template_of[nid] != owner)
            if crossed:
                merged_tasks += 1
                merged_requests += crossed
        return {"cross_template_merged_tasks": merged_tasks,
                "cross_template_merged_requests": merged_requests}

    # ------------------------------------------------------------------
    def run(self, cons: ConsolidatedGraph, plan: ExecutionPlan,
            checkpoint_path: Optional[str] = None,
            resume_from: Optional[str] = None,
            die_after: Optional[Dict[int, int]] = None,
            hosts: Optional[List[EngineHost]] = None,
            optimizer=None) -> RunReport:
        """Execute the consolidated batch. Returns a RunReport whose
        ``extra['results']`` holds the per-(query,node) outputs.

        ``hosts`` lets an online driver keep engines (resident models,
        warm KV pages) alive across successive micro-batches; by default
        each run gets fresh hosts.  ``optimizer`` (an OnlineOptimizer)
        enables cost calibration + mid-run replanning; like ``hosts`` it
        may persist across runs so calibration compounds."""
        # multi-template mega-DAGs restrict each namespaced node to its
        # own template's query slice; single-template maps to all queries
        state = BatchState(self.graph, cons.n_queries,
                           queries_of=cons.queries_map())
        if resume_from:
            restored = load_batch_state(state, resume_from)
        else:
            restored = 0

        records: List[TaskRecord] = []
        rlock = threading.Lock()
        t0 = time.perf_counter()
        board = PlanBoard(plan, self.graph.llm_dag(), self.W)
        base_replans = 0
        if optimizer is not None:
            optimizer.bind_graph(self.graph)   # decode_cap-rewritten copy
            optimizer.solver_config.num_workers = self.W
            # replans must price placement moves the way THIS processor
            # executes them: no migration credit when migration is off
            optimizer.cm.use_migration = self.kv_migration
            optimizer.attach_plan(plan)
            base_replans = optimizer.replans

        dispatcher = ToolDispatcher(
            self.graph, state, cons.bindings, self.tools, records, rlock,
            t0, cpu_slots=self.cpu_slots, coalescing=self.coalescing,
            optimizer=optimizer)
        dispatcher.start()

        own_hosts = hosts is None
        if hosts is None:
            hosts = [EngineHost(self.model_configs, seed=self.seed,
                                engine_kwargs=self.engine_kwargs)
                     for _ in range(self.W)]
        assert len(hosts) == self.W
        base = self._engine_totals(hosts)       # persistent-host baseline
        for h in hosts:                         # per-run peak watermark
            for e in h._engines.values():
                e.reset_peak_batch()

        migrator = None
        if self.kv_migration:
            # no optimizer -> no replanning, but workers still pull warm
            # lineage from peers at claim time (cost-model decision falls
            # back to migrate-on-hit without a cm)
            migrator = KVMigrator(
                self.graph, hosts,
                cost_model=optimizer.cm if optimizer is not None else None)

        workers = [
            GPUWorkerThread(w, board, self.graph, state, cons.bindings,
                            hosts[w], records, rlock, t0,
                            die_after=(die_after or {}).get(w),
                            pipelining=self.pipelining, optimizer=optimizer,
                            migrator=migrator, claim_ahead=self.claim_ahead)
            for w in range(self.W)]
        try:
            if optimizer is not None:
                # admission-time pass: a queued (forced) splice — or a
                # plan already known-drifted from a prior micro-batch —
                # re-places work and migrates warm KV before any claim
                optimizer.maybe_replan(board, migrator=migrator)
            for wk in workers:
                wk.start()
            deadline = time.monotonic() + 600.0
            while any(wk.is_alive() for wk in workers):
                if any(wk.error for wk in workers) or dispatcher.error:
                    break
                for wk in workers:
                    wk.join(timeout=0.05)
                if optimizer is not None:
                    optimizer.maybe_replan(board, migrator=migrator)
                if time.monotonic() > deadline:
                    break
            err = next((wk.error for wk in workers if wk.error), None) \
                or dispatcher.error
            if err is None:
                # results land from engine callbacks; tool tasks may still
                # be draining — wait for full completion (or a late
                # failure, which also notifies the state lock), then stop
                target = len(self.graph.nodes)
                with state.lock:
                    state.lock.wait_for(
                        lambda: (len(state.macro_done) == target
                                 or dispatcher.error is not None
                                 or any(wk.error for wk in workers)),
                        timeout=60.0)
            dispatcher.stop()
            dispatcher.join(timeout=60)

            err = err or next((wk.error for wk in workers if wk.error),
                              None) or dispatcher.error
            if err is not None:
                raise err
            if not state.all_done():
                missing = set(self.graph.nodes) - state.macro_done
                raise RuntimeError(
                    f"run incomplete; missing {sorted(missing)}")
        finally:
            dispatcher.stop()           # idempotent; covers raise paths
            dispatcher.join(timeout=60)
            if own_hosts:               # persistent hosts outlive the run
                for h in hosts:
                    h.shutdown()

        if checkpoint_path:
            save_batch_state(state, checkpoint_path)

        report = RunReport(
            name=plan.scheduler_name, makespan=time.perf_counter() - t0,
            records=records, num_queries=cons.n_queries, num_workers=self.W)
        report.coalesce_stats = {
            "tool_logical": dispatcher.table.logical_requests,
            "tool_physical": dispatcher.table.physical_executions,
            "tool_dedup_ratio": dispatcher.table.dedup_ratio,
            "restored_results": restored,
        }
        if cons.n_templates > 1:
            report.coalesce_stats.update(
                self._cross_template_stats(cons, dispatcher.table))
        report.extra["results"] = {           # type: ignore[assignment]
            f"{q}:{node}": val
            for (q, node), val in sorted(state.results.items())}
        # per-run deltas against the at-start totals: persistent hosts
        # must not re-report earlier micro-batches' counts
        totals = self._engine_totals(hosts)
        for key, cur in totals.items():
            report.extra[key] = max(cur - base.get(key, 0), 0)
        engines = [e for h in hosts for e in h._engines.values()]
        # per-run gauge: watermarks were reset at run start, so the max
        # is THIS run's peak concurrency, not an earlier run's
        report.extra["peak_batch"] = max(
            (e.stats.peak_batch for e in engines), default=0)
        report.extra["cpu_gpu_overlap_s"] = round(
            report.cpu_gpu_overlap(), 6)
        report.extra["plan_splices"] = board.splices
        if optimizer is not None:
            report.extra["replans"] = optimizer.replans - base_replans
            report.extra["calibration"] = (   # type: ignore[assignment]
                optimizer.calibration_summary())
        if migrator is not None:
            report.extra["migration"] = (     # type: ignore[assignment]
                migrator.summary())
        return report
