"""RealProcessor — executes an ExecutionPlan with REAL components
(DESIGN.md §7):

tiny JAX models behind InferenceEngines (continuous batching, prefix
sharing, model switching), the minidb ToolRuntime, signature coalescing,
per-query wavefront tool promotion, checkpoint/restart and worker-failure
recovery.  The scheduling logic is the SAME code the simulator drives —
real mode exists to prove the semantics: coalescing, plan choice,
per-request pipelining and mid-run replanning must not change outputs
(asserted in tests).

Since the session redesign (DESIGN.md §10), ``run()`` is a thin
ONE-SHOT wrapper over ``ProcessorSession``: open a session, bootstrap
it with the consolidated batch, drain, report, close.  Streaming
callers should hold a ``ProcessorSession`` directly and ``submit()``
into it — arriving queries then graft into the running mega-DAG
instead of waiting for the next ``run()``.

Construction takes a ``ProcessorConfig``; the former 11 loose keyword
arguments are still accepted for one release behind a
``DeprecationWarning`` shim.
"""
from __future__ import annotations

import warnings
from dataclasses import fields, replace
from typing import Any, Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.consolidate import ConsolidatedGraph
from repro.core.graphspec import GraphSpec
from repro.core.plan import ExecutionPlan
from repro.runtime.jobstore import save_batch_state
from repro.runtime.events import RunReport
from repro.runtime.executors import EngineHost
from repro.runtime.session import ProcessorConfig, ProcessorSession
from repro.workloads.tools import ToolRuntime

_CONFIG_FIELDS = {f.name for f in fields(ProcessorConfig)}


class RealProcessor:
    """One-shot real-mode Processor facade over ``ProcessorSession``."""

    def __init__(self, graph: GraphSpec,
                 model_configs: Dict[str, ModelConfig],
                 tools: ToolRuntime,
                 config: Optional[ProcessorConfig] = None,
                 **legacy: Any):
        if legacy:
            unknown = set(legacy) - _CONFIG_FIELDS
            if unknown:
                raise TypeError(
                    f"unknown RealProcessor arguments: {sorted(unknown)}")
            warnings.warn(
                "passing loose keyword arguments to RealProcessor is "
                "deprecated; pass config=ProcessorConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            config = replace(config or ProcessorConfig(), **legacy)
        self.config = config or ProcessorConfig()
        self.model_configs = model_configs
        self.tools = tools
        self.W = self.config.num_workers
        self.cpu_slots = self.config.cpu_slots
        self.coalescing = self.config.coalescing
        self.seed = self.config.seed
        self.pipelining = self.config.pipelining
        self.engine_kwargs = self.config.engine_kwargs
        self.kv_migration = self.config.kv_migration
        self.claim_ahead = self.config.claim_ahead
        self.graph = graph
        # cap generation length in tests (CPU real mode); None = node spec
        if self.config.decode_cap is not None:
            cap = self.config.decode_cap
            nodes = [n.with_(max_new_tokens=min(n.max_new_tokens, cap))
                     if n.is_llm() else n for n in graph.nodes.values()]
            self.graph = GraphSpec(graph.name, nodes, graph.edges)

    # ------------------------------------------------------------------
    def run(self, cons: ConsolidatedGraph, plan: ExecutionPlan,
            checkpoint_path: Optional[str] = None,
            resume_from: Optional[str] = None,
            die_after: Optional[Dict[int, int]] = None,
            hosts: Optional[List[EngineHost]] = None,
            optimizer=None) -> RunReport:
        """Execute the consolidated batch as one session: bootstrap →
        drain → report.  ``RunReport.results()`` holds the per-(query,
        node) outputs.

        ``hosts`` lets an online driver keep engines (resident models,
        warm KV pages) alive across successive micro-batches; by default
        each run gets fresh hosts.  ``optimizer`` (an OnlineOptimizer)
        enables cost calibration + mid-run replanning; like ``hosts`` it
        may persist across runs so calibration compounds."""
        session = ProcessorSession(self.model_configs, self.tools,
                                   config=self.config)
        session.open(hosts=hosts, optimizer=optimizer)
        try:
            session.submit_consolidated(cons, plan, graph=self.graph,
                                        resume_from=resume_from,
                                        die_after=die_after)
            session.drain(timeout=600.0)
            if checkpoint_path:
                save_batch_state(session.state, checkpoint_path)
            return session.report()
        finally:
            session.close()
