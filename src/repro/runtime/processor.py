"""RealProcessor — executes an ExecutionPlan with REAL components:

tiny JAX models behind InferenceEngines (continuous batching, prefix
sharing, model switching), the minidb ToolRuntime, signature coalescing,
per-query wavefront tool promotion, checkpoint/restart and worker-failure
recovery.  The scheduling logic is the SAME code the simulator drives —
real mode exists to prove the semantics: coalescing and plan choice must
not change outputs (asserted in tests).
"""
from __future__ import annotations

import queue as _q
import threading
import time
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.consolidate import ConsolidatedGraph
from repro.core.graphspec import GraphSpec
from repro.core.plan import ExecutionPlan
from repro.runtime.checkpoint import load_batch_state, save_batch_state
from repro.runtime.coordinator import BatchState
from repro.runtime.events import RunReport, TaskRecord
from repro.runtime.executors import (EngineHost, GPUWorkerThread,
                                     ToolDispatcher)
from repro.workloads.tools import ToolRuntime


class RealProcessor:
    def __init__(self, graph: GraphSpec, model_configs: Dict[str, ModelConfig],
                 tools: ToolRuntime, num_workers: int = 2,
                 cpu_slots: int = 8, coalescing: bool = True, seed: int = 0,
                 decode_cap: Optional[int] = None):
        self.graph = graph
        self.model_configs = model_configs
        self.tools = tools
        self.W = num_workers
        self.cpu_slots = cpu_slots
        self.coalescing = coalescing
        self.seed = seed
        # cap generation length in tests (CPU real mode); None = node spec
        if decode_cap is not None:
            nodes = [n.with_(max_new_tokens=min(n.max_new_tokens, decode_cap))
                     if n.is_llm() else n for n in graph.nodes.values()]
            self.graph = GraphSpec(graph.name, nodes, graph.edges)

    # ------------------------------------------------------------------
    def run(self, cons: ConsolidatedGraph, plan: ExecutionPlan,
            checkpoint_path: Optional[str] = None,
            resume_from: Optional[str] = None,
            die_after: Optional[Dict[int, int]] = None,
            hosts: Optional[List[EngineHost]] = None) -> RunReport:
        """Execute the consolidated batch. Returns a RunReport whose
        ``extra['results']`` holds the per-(query,node) outputs.

        ``hosts`` lets an online driver keep engines (resident models,
        warm KV pages) alive across successive micro-batches; by default
        each run gets fresh hosts."""
        state = BatchState(self.graph, cons.n_queries)
        if resume_from:
            restored = load_batch_state(state, resume_from)
        else:
            restored = 0

        records: List[TaskRecord] = []
        rlock = threading.Lock()
        t0 = time.perf_counter()
        overflow: "_q.SimpleQueue[str]" = _q.SimpleQueue()

        dispatcher = ToolDispatcher(
            self.graph, state, cons.bindings, self.tools, records, rlock,
            t0, cpu_slots=self.cpu_slots, coalescing=self.coalescing)
        dispatcher.start()

        seqs = plan.worker_sequences(self.W)
        own_hosts = hosts is None
        if hosts is None:
            hosts = [EngineHost(self.model_configs, seed=self.seed)
                     for _ in range(self.W)]
        assert len(hosts) == self.W
        workers = [
            GPUWorkerThread(w, seqs[w], self.graph, state, cons.bindings,
                            hosts[w], records, rlock, t0, overflow,
                            die_after=(die_after or {}).get(w))
            for w in range(self.W)]
        try:
            for wk in workers:
                wk.start()
            for wk in workers:
                wk.join(timeout=600)
            dispatcher.stop_flag.set()
            dispatcher.join(timeout=60)

            for wk in workers:
                if wk.error:
                    raise wk.error
            if dispatcher.error:
                raise dispatcher.error
            if not state.all_done():
                missing = set(self.graph.nodes) - state.macro_done
                raise RuntimeError(
                    f"run incomplete; missing {sorted(missing)}")
        finally:
            if own_hosts:               # persistent hosts outlive the run
                for h in hosts:
                    h.shutdown()

        if checkpoint_path:
            save_batch_state(state, checkpoint_path)

        report = RunReport(
            name=plan.scheduler_name, makespan=time.perf_counter() - t0,
            records=records, num_queries=cons.n_queries, num_workers=self.W)
        report.coalesce_stats = {
            "tool_logical": dispatcher.table.logical_requests,
            "tool_physical": dispatcher.table.physical_executions,
            "tool_dedup_ratio": dispatcher.table.dedup_ratio,
            "restored_results": restored,
        }
        report.extra["results"] = {           # type: ignore[assignment]
            f"{q}:{node}": val
            for (q, node), val in sorted(state.results.items())}
        report.extra["model_switches"] = sum(h.switches for h in hosts)
        engines = [e for h in hosts for e in h._engines.values()]
        for key in ("prefill_tokens_saved", "admission_waves",
                    "pages_shared", "tokens_reused", "coalesced_requests"):
            report.extra[key] = sum(getattr(e.stats, key) for e in engines)
        report.extra["peak_batch"] = max(
            (e.stats.peak_batch for e in engines), default=0)
        return report
