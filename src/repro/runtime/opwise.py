"""OpWise baseline executor (§6.1) — stage-synchronous MapReduce-style.

OpWise buffers ALL requests at a topological stage, then dispatches the
pooled (node × wave) units across workers to maximize instantaneous
batch size.  Consequences the paper measures, reproduced mechanically:

* strict barrier between stages (no CPU–GPU overlap: stage tools run as
  a serial phase before the stage's LLM work);
* a worker's consecutive units interleave models within a stage
  → repeated weight reloads (model thrash);
* stage latency = the SLOWEST worker's unit sum (straggler waste).
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.core.consolidate import ConsolidatedGraph
from repro.core.cost_model import CostModel
from repro.core.graphspec import GraphSpec
from repro.core.state import WorkerContext
from repro.runtime.events import RunReport, TaskRecord


class OpWiseSimulator:
    def __init__(self, graph: GraphSpec, cost_model: CostModel,
                 num_workers: int, cpu_slots: int = 16,
                 coalescing: bool = True, processor_batch: int = 256):
        self.graph = graph
        self.cm = cost_model
        self.W = num_workers
        self.cpu_slots = cpu_slots
        self.coalescing = coalescing
        self.processor_batch = processor_batch

    # ------------------------------------------------------------------
    def _levels(self) -> List[List[str]]:
        level: Dict[str, int] = {}
        for v in self.graph.topo_order():
            if not self.graph.nodes[v].is_llm():
                continue
            ps = [p for p in self.graph.parents(v)
                  if self.graph.nodes[p].is_llm()]
            # LLM level also considers LLM ancestors through tool nodes
            anc = [a for a in self.graph.ancestors(v)
                   if self.graph.nodes[a].is_llm()]
            level[v] = 1 + max((level[a] for a in anc if a in level),
                               default=-1)
        out: List[List[str]] = [[] for _ in range(max(level.values()) + 1)]
        for v, lv in level.items():
            out[lv].append(v)
        return out

    def _n_phys(self, cons: ConsolidatedGraph, nid: str) -> int:
        m = cons.macro(nid)
        if self.graph.nodes[nid].is_llm():
            return m.n_logical                 # LLM calls are never deduped
        if not self.coalescing:
            return m.n_logical
        return len(cons.physical_signatures(nid))   # cross-template aware

    # ------------------------------------------------------------------
    def run(self, cons: ConsolidatedGraph) -> RunReport:
        report = RunReport(name="opwise", num_workers=self.W,
                           num_queries=cons.n_queries)
        t = 0.0
        ctxs = [WorkerContext() for _ in range(self.W)]
        done_tools: set = set()
        log_tools = phys_tools = 0

        for stage in self._levels():
            # ---- serial CPU phase: all tools feeding this stage ----------
            pend: List[str] = []
            for v in stage:
                for tnode in self.graph.tool_ancestors_between(v):
                    if tnode not in done_tools:
                        pend.append(tnode)
                        done_tools.add(tnode)
            if pend:
                tool_time = 0.0
                for tnode in pend:
                    n = self._n_phys(cons, tnode)
                    est = self.cm.profiler.estimate(self.graph.nodes[tnode])
                    dur = est * math.ceil(n / self.cpu_slots)
                    tool_time = max(tool_time, dur)    # pool runs them together
                    log_tools += cons.macro(tnode).n_logical
                    phys_tools += n
                total_work = sum(
                    self.cm.profiler.estimate(self.graph.nodes[tn])
                    * self._n_phys(cons, tn) for tn in pend)
                tool_time = max(tool_time, total_work / self.cpu_slots)
                report.records.append(TaskRecord(
                    node="+".join(pend[:3]), kind="tool", worker="cpu",
                    start=t, end=t + tool_time, batch=phys_tools))
                t += tool_time                        # BARRIER: GPUs idle

            # ---- pooled GPU phase ----------------------------------------
            # one node -> one engine/worker (same batch processor as Halo);
            # its buffered requests run as consecutive processor_batch waves
            free = [t] * self.W
            for v in stage:
                w = min(range(self.W), key=lambda x: free[x])
                spec = self.graph.nodes[v]
                llm_parents = [p for p in self.graph.parents(v)
                               if self.graph.nodes[p].is_llm()]
                n = self._n_phys(cons, v)
                old = self.cm.batch_sizes.get(v)
                start = free[w]
                total_batch = n
                while n > 0:
                    wave_n = min(self.processor_batch, n)
                    self.cm.batch_sizes[v] = wave_n
                    dur = (self.cm.t_model(spec, ctxs[w])
                           + self.cm.t_infer(spec, ctxs[w], llm_parents))
                    free[w] += dur
                    ctxs[w] = ctxs[w].after(v, spec.model)
                    n -= wave_n
                if old is None:
                    self.cm.batch_sizes.pop(v, None)
                else:
                    self.cm.batch_sizes[v] = old
                report.records.append(TaskRecord(
                    node=v, kind="llm", worker=f"gpu{w}", start=start,
                    end=free[w], batch=total_batch))
            t = max(free) if stage else t              # stage barrier

        report.makespan = t
        report.coalesce_stats = {
            "tool_logical": log_tools, "tool_physical": phys_tools,
            "tool_dedup_ratio": phys_tools / max(log_tools, 1),
        }
        return report
