"""Cross-worker KV-cache migration (paper §5: the Processor's
"KV-cache sharing and **migration**"; DESIGN.md §7.3).

Prefix sharing keeps a node's warm KV useful only on the worker that
computed it.  When a mid-run replan splices a node onto a DIFFERENT
worker, its warm parent-lineage pages would strand on the old host and
the new host would re-prefill the whole prompt from scratch — replanning
would tax locality exactly where it should pay.  ``KVMigrator`` closes
that gap:

* on every plan splice it diffs the per-worker assignments (old board
  sequences vs the new tail) and, for each moved LLM node, looks up the
  prompts that node — and its LLM parents, the lineage the cost model's
  warm credit refers to — last ran with on the source host;
* each prompt's warm prefix is probed on the source engine, the
  migrate-vs-recompute decision is priced with the cost model's roofline
  (transfer over the modeled worker↔worker link vs re-prefilling the
  same tokens), and winners are exported (contiguous KV copy) and
  imported into the destination engine, which stamps its radix tree so
  the node's first admission wave aliases the pages;
* transfers are priced at ``link_bandwidth`` and accounted on the
  engines (``pages_migrated_in/out``, ``migrate_seconds``) and on the
  migrator itself for RunReport surfacing.

Migration runs BEFORE ``PlanBoard.splice`` publishes the new tail, so a
moved node's first wave on the new worker already sees the warm pages.
It is strictly best-effort and semantics-free: imported pages are just
extra warm donors, and temperature-0 outputs are bitwise-identical with
migration on, off, or forced (asserted in tests).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost_model import CostModel
from repro.core.graphspec import GraphSpec
from repro.debugsync import named_lock


class KVMigrator:
    """Moves warm KV prefixes between EngineHosts when a splice moves
    their nodes."""

    def __init__(self, graph: GraphSpec, hosts: Sequence,
                 cost_model: Optional[CostModel] = None,
                 link_bandwidth: Optional[float] = None):
        self.graph = graph                       # swap-only
        self.hosts = list(hosts)
        self.cm = cost_model
        # the wire model pricing migrate_seconds MUST be the same link
        # the cost model's migrate-vs-recompute decision assumed, or the
        # accounted transfer time can exceed the re-prefill time the
        # decision claimed to beat
        if link_bandwidth is None:
            link_bandwidth = (cost_model.hw.link_bw
                              if cost_model is not None else 16e9)
        self.link_bandwidth = link_bandwidth     # bytes/s
        # serializes the outcome counters: splice-time migration (the
        # monitor thread) and claim-time pulls (worker threads) overlap
        self.lock = named_lock("KVMigrator.lock")
        # outcomes (RunReport surfacing): assignment changes seen, moves
        # with >=1 prefix sent, modeled link-transfer seconds, transfers
        # lost to re-prefill, best-effort failures swallowed
        self.nodes_moved = 0                    # guarded-by: self.lock
        self.nodes_migrated = 0                 # guarded-by: self.lock
        self.prefixes_migrated = 0              # guarded-by: self.lock
        self.pages_migrated = 0                 # guarded-by: self.lock
        self.tokens_migrated = 0                # guarded-by: self.lock
        self.migrate_seconds = 0.0              # guarded-by: self.lock
        self.skipped_recompute = 0              # guarded-by: self.lock
        self.transfer_errors = 0                # guarded-by: self.lock

    # ------------------------------------------------------------------
    def assignment_diff(self, board, tail) -> List[Tuple[str, int, int]]:
        """(node, old_worker, new_worker) for every still-unclaimed node
        the new tail places on a different worker than the live board."""
        old = board.planned_assignments()
        moves = []
        for w, seq in enumerate(tail.worker_sequences(board.W)):
            for n in seq:
                if n in old and old[n] != w:
                    moves.append((n, old[n], w))
        return sorted(moves)

    def migrate_for_splice(self, board, tail) -> int:
        """Migrate warm lineage prefixes for the nodes ``tail`` places.

        Every still-unclaimed node is considered, not just the ones the
        splice MOVES: the solver's peer-context credit prices a warm
        lineage held on any other worker, so realizing it only for
        assignment changes would leave the unmoved-but-remote-warm case
        as phantom savings.  The node's previous worker is tried first
        (that is where a move strands the warmest data), then the rest.
        Returns the number of prefixes transferred."""
        old = board.planned_assignments()
        total = 0
        for dst_w, seq in enumerate(tail.worker_sequences(board.W)):
            for nid in seq:
                if nid not in old:               # claimed meanwhile
                    continue
                if old[nid] != dst_w:
                    with self.lock:
                        self.nodes_moved += 1
                sources = [old[nid]] if old[nid] != dst_w else []
                sources += [w for w in range(board.W)
                            if w != dst_w and w not in sources]
                total += self._migrate_node(nid, sources, dst_w)
        return total

    # ------------------------------------------------------------------
    def migrate_node_from_peers(self, nid: str, dst_w: int) -> int:
        """Pull ``nid``'s warm lineage from every OTHER worker right
        before its first wave runs on ``dst_w``.

        This is the claim-time realization of the cost model's peer
        credit: splice-time migration only sees KV that existed when the
        splice fired, but a parent that completes afterwards (or a plan
        that never drifts at all) still leaves warm lineage on peers —
        the worker pulls it here, so the solver's priced savings
        materialize for unmoved nodes too."""
        sources = [w for w in range(len(self.hosts)) if w != dst_w]
        return self._migrate_node(nid, sources, dst_w)

    def _alias_ids(self, nid: str) -> Sequence[str]:
        """Cross-template warm aliases of ``nid`` (multi-template mega-
        DAGs): nodes whose identical upstream subtree makes their warm
        KV interchangeable with ``nid``'s.  The cost model prices these
        as donors, so the migrator must probe them too or the planner's
        credit would be savings execution never realizes."""
        if self.cm is None:
            return ()
        return self.cm.warm_aliases.get(nid, ())

    def _lineage_prompts(self, nid: str, host) -> List[tuple]:
        """Recent prompts of ``nid`` / its LLM parents / their warm
        aliases on ``host`` — the node's warm lineage, newest first,
        deduplicated."""
        cand: List[tuple] = list(host.prompts_for(nid))
        for a in self._alias_ids(nid):
            cand.extend(host.prompts_for(a))
        for p in self.graph.parents(nid):
            if self.graph.nodes[p].is_llm():
                cand.extend(host.prompts_for(p))
                for a in self._alias_ids(p):
                    cand.extend(host.prompts_for(a))
        seen: set = set()
        out: List[tuple] = []
        for prompt in reversed(cand):            # newest first
            if prompt not in seen:
                seen.add(prompt)
                out.append(prompt)
        return out

    def _migrate_node(self, nid: str, src_workers: Sequence[int],
                      dst_w: int) -> int:
        """Best-effort by contract: every per-prefix failure (step-gap
        timeout, pool pressure, eviction races) is swallowed and counted
        — a migration problem must never fail the batch it was trying
        to speed up."""
        spec = self.graph.nodes[nid]
        sent = 0
        for src_w in src_workers:
            src = self.hosts[src_w].peek_engine(spec.model)
            if src is None:                      # model never ran there
                continue
            for prompt in self._lineage_prompts(nid, self.hosts[src_w]):
                try:
                    sent += self._migrate_prefix(spec, src, dst_w, prompt)
                except Exception:
                    with self.lock:
                        self.transfer_errors += 1
        if sent:
            with self.lock:
                self.nodes_migrated += 1
        return sent

    def _migrate_prefix(self, spec, src, dst_w: int, prompt: tuple) -> int:
        depth = src.probe_prefix(prompt)
        if depth <= 0:
            return 0
        if self.cm is not None and spec.model in self.cm.models \
                and not self.cm.migration_wins(spec, depth):
            with self.lock:
                self.skipped_recompute += 1
            return 0
        dst = self.hosts[dst_w].engine_for_import(spec.model)
        if dst.probe_prefix(prompt) >= depth:
            return 0                             # destination already warm
        exported = src.export_prefix(prompt)
        if exported is None:
            return 0                             # evicted since the probe
        tokens, k, v = exported
        if self.cm is not None and spec.model in self.cm.models:
            # the SAME wire model the migrate-vs-recompute decision
            # used — accounted seconds must not contradict it
            seconds = self.cm.t_migrate(spec, len(tokens))
        else:
            seconds = (k.nbytes + v.nbytes) / self.link_bandwidth
        pages = dst.import_prefix(tokens, k, v, migrate_seconds=seconds)
        if not pages:
            return 0
        # out-counter on CONFIRMED import only, so in/out track real
        # transfers symmetrically
        src.stats.pages_migrated_out += pages
        with self.lock:
            self.prefixes_migrated += 1
            self.pages_migrated += pages
            self.tokens_migrated += len(tokens)
            self.migrate_seconds += seconds
        return 1

    # ------------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        with self.lock:
            return {
                "nodes_moved": self.nodes_moved,
                "nodes_migrated": self.nodes_migrated,
                "prefixes_migrated": self.prefixes_migrated,
                "pages_migrated": self.pages_migrated,
                "tokens_migrated": self.tokens_migrated,
                "migrate_seconds": round(self.migrate_seconds, 9),
                "skipped_recompute": self.skipped_recompute,
                "transfer_errors": self.transfer_errors,
            }
