"""Online cost calibration + mid-batch replanning (paper §5's feedback
loop from the Processor back into the Optimizer; DESIGN.md §7.2).

``OnlineOptimizer`` sits between the real executors and the planning
stack:

* every completed tool task feeds ``OperatorProfiler.update()`` (the
  EXPLAIN/EWMA terms of T_prep);
* every completed LLM macro-node feeds ``HardwareCalibration`` — the
  observed latency is split into its predicted prefill/decode shares and
  the roofline's effective ``mfu``/``bw_eff`` knobs are re-fit, then
  substituted back into the live CostModel;
* after each plan epoch fully completes, the observed epoch cost (same
  mu/lambda blend the solver scored) is compared against the epoch's
  predicted cost; past ``drift_threshold`` the remaining LLM DAG is
  re-solved from the live SystemState (claimed nodes + per-worker
  contexts) and the new tail is spliced into the PlanBoard.

The spliced plan (claimed prefix as singleton epochs + re-solved tail)
is validated against the DAG before splicing — replanning can only ever
reorder *unclaimed* work, so outputs are untouched (asserted in tests).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.cost_model import CostModel, HardwareCalibration
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.solver import EpochDPSolver, SolverConfig
from repro.core.state import SystemState
from repro.debugsync import named_lock
from repro.runtime.coordinator import PlanBoard


class OnlineOptimizer:
    """Continuously calibrated cost model + mid-run replanner."""

    def __init__(self, cost_model: CostModel,
                 solver_config: Optional[SolverConfig] = None,
                 drift_threshold: float = 0.35,
                 calibration_alpha: float = 0.5,
                 max_replans: int = 8):
        self.cm = cost_model
        # the live LLM DAG: rebound whole by bind_graph/adopt_graft,
        # read lock-free by the replan path (one coherent snapshot)
        self.dag = cost_model.graph.llm_dag()       # swap-only
        self.solver_config = solver_config or SolverConfig()
        self.drift_threshold = drift_threshold
        self.calib = HardwareCalibration(cost_model.hw,
                                         alpha=calibration_alpha)
        self.max_replans = max_replans
        # serializes calibration/observation state: workers observe from
        # their own threads while the monitor loop evaluates drift
        self.lock = named_lock("OnlineOptimizer.lock")
        # plan bookkeeping
        self.plan: Optional[ExecutionPlan] = None   # guarded-by: self.lock
        self._epoch_nodes: List[List[str]] = []     # guarded-by: self.lock
        self._evaluated: set = set()                # guarded-by: self.lock
        # nid -> (worker, seconds); waves of unfinished nodes
        self._llm_obs: Dict[str, tuple] = {}        # guarded-by: self.lock
        self._llm_partial: Dict[str, tuple] = {}    # guarded-by: self.lock
        # outcomes
        self.replans = 0                            # guarded-by: self.lock
        self.epoch_drifts: List[Dict[str, float]] = []  # guarded-by: self.lock
        # |pred-obs|/obs per LLM node
        self.predicted_errors: List[float] = []     # guarded-by: self.lock
        self.spliced_plan: Optional[ExecutionPlan] = None  # guarded-by: self.lock
        self._queued_tail: Optional[ExecutionPlan] = None  # guarded-by: self.lock
        # per-node SLO priority mass (session grafts set this); drift
        # replans re-solve with the same weights the graft solve used,
        # so a replan never silently drops the interactive lanes
        self.node_priorities: Dict[str, float] = {}  # guarded-by: self.lock

    # ------------------------------------------------------------------
    def bind_graph(self, graph) -> None:
        """Point the cost model at the graph the Processor actually
        executes.  RealProcessor rewrites ``max_new_tokens`` onto a copy
        when ``decode_cap`` is set; calibrating against the caller's
        uncapped graph would price decode work that never runs."""
        if self.cm.graph is graph:
            return
        if set(self.cm.graph.nodes) != set(graph.nodes):
            raise ValueError(
                "optimizer cost model was built for a different workflow "
                f"({self.cm.graph.name!r} vs {graph.name!r})")
        with self.lock:
            self.cm.graph = graph
            self.dag = graph.llm_dag()

    def adopt_graft(self, graph, batch_sizes: Dict[str, int],
                    warm_aliases: Optional[Dict[str, tuple]] = None,
                    node_priorities: Optional[Dict[str, float]] = None
                    ) -> None:
        """Point the live cost model at a grafted SUPERGRAPH mid-run
        (DESIGN.md §10.2).

        Unlike ``bind_graph`` the node set is allowed to GROW: a session
        graft extends the running mega-DAG, and subsequent drift replans
        must price the new nodes too.  Calibration state (roofline knobs,
        tool EWMAs) and per-node observations persist — that continuity
        is the point of grafting into a live session instead of starting
        a fresh run.
        """
        missing = set(self.cm.graph.nodes) - set(graph.nodes)
        if missing:
            raise ValueError(
                f"graft graph dropped existing nodes: {sorted(missing)}")
        with self.lock:
            self.cm.graph = graph
            self.dag = graph.llm_dag()
            self.cm.batch_sizes = dict(batch_sizes)
            if warm_aliases is not None:
                self.cm.warm_aliases = dict(warm_aliases)
            if node_priorities is not None:
                self.node_priorities = dict(node_priorities)

    def attach_plan(self, plan: ExecutionPlan, fresh: bool = True,
                    evaluated_prefix: int = 0) -> None:
        """Start tracking ``plan``'s epochs.

        ``fresh=True`` (a new run) clears the per-run node observations;
        ``fresh=False`` (a mid-run splice) keeps them.  A splice passes
        ``evaluated_prefix`` = its claimed-prefix length: those singleton
        epochs are history with no solver-predicted cost (Epoch defaults
        to 0.0), so evaluating drift on them would divide by ~0 and
        re-trigger replanning forever.  Calibration state (roofline
        knobs, tool EWMAs) always persists — that is the whole point of
        reusing one optimizer across micro-batches.
        """
        with self.lock:
            self.plan = plan
            if fresh:
                self._llm_obs = {}
                self._llm_partial = {}
            self._epoch_nodes = [
                [v for comp in e.components for v in comp]
                for e in plan.epochs]
            self._evaluated = set(range(evaluated_prefix)) | {
                i for i, nodes in enumerate(self._epoch_nodes)
                if nodes and all(n in self._llm_obs for n in nodes)}

    # --------------------------------------------------- observations
    def observe_tool(self, node_id: str, op: str, seconds: float) -> None:
        with self.lock:
            self.cm.profiler.update(node_id, op, seconds)

    @staticmethod
    def _union_seconds(spans: List[tuple]) -> float:
        """Total length of the union of (start, end) intervals —
        concurrent waves of one continuous batch must not double-count
        the shared busy time."""
        total = 0.0
        hi = float("-inf")
        for s, e in sorted(spans):
            if s > hi:
                total += e - s
                hi = e
            elif e > hi:
                total += e - hi
                hi = e
        return total

    def observe_llm(self, node_id: str, batch: int, seconds: float,
                    worker: str = "", node_complete: bool = True,
                    span: Optional[tuple] = None) -> None:
        """Measured LLM latency → roofline knob re-fit.

        Pipelined workers report once per submission wave (``batch`` =
        wave size, ``node_complete`` only on the node's last wave); the
        barrier path reports the whole macro-node at once.  Epoch drift
        is evaluated on a node only once it is complete, over the UNION
        of its waves' ``span`` intervals (waves can overlap inside one
        continuous batch).  Calibration treats each wave's sample
        independently — concurrent waves share the engine, so individual
        samples are noisy and the EWMA does the smoothing.
        """
        spec = self.cm.graph.nodes[node_id]
        with self.lock:
            tp, td = self.cm.infer_breakdown(spec, batch)
            if tp + td > 0 and seconds > 0:
                self.predicted_errors.append(
                    abs((tp + td) - seconds) / seconds)
            self.calib.observe(tp, td, seconds)
            self.cm.hw = self.calib.profile()
            _, spans, plain = self._llm_partial.get(node_id,
                                                    (worker, [], 0.0))
            if span is not None:
                spans = spans + [tuple(span)]
            else:                       # span-less callers: plain summing
                plain += seconds
            if node_complete:
                self._llm_partial.pop(node_id, None)
                self._llm_obs[node_id] = (
                    worker, plain + self._union_seconds(spans))
            else:
                self._llm_partial[node_id] = (worker, spans, plain)

    # ----------------------------------------------------- replanning
    # requires: self.lock
    def _observed_epoch_cost(self, nodes: List[str]) -> float:
        """Observed per-worker busy times scored with the SAME blend the
        solver used for the prediction (CostModel.epoch_blend)."""
        busy: Dict[str, float] = {}
        for n in nodes:
            w, s = self._llm_obs[n]
            busy[w] = busy.get(w, 0.0) + s
        return self.cm.epoch_blend(list(busy.values()))

    def queue_splice(self, tail: ExecutionPlan) -> None:
        """Queue an explicit tail plan to splice on the next
        ``maybe_replan`` call, bypassing the drift trigger.

        This is the FORCED-replan hook (A/B benchmarks, migration
        tests, admission-time re-placement from a prior micro-batch's
        calibration): the tail's placement replaces every worker's
        unclaimed sequence, and any node it moves across workers gets
        its warm KV lineage migrated first when a migrator is active.
        """
        with self.lock:
            self._queued_tail = tail

    def maybe_replan(self, board: PlanBoard, migrator=None) -> bool:
        """Evaluate drift on freshly completed epochs; replan past the
        threshold.  Called from the Processor's monitor loop (and once
        before workers start, which is when a queued splice fires).

        ``migrator`` (a KVMigrator) migrates moved nodes' warm KV
        lineage before the splice publishes the new assignments."""
        with self.lock:
            queued, self._queued_tail = self._queued_tail, None
        if queued is not None:
            return self._apply_tail(board, queued, migrator)
        with self.lock:
            if self.plan is None or self.replans >= self.max_replans:
                return False
            trigger = False
            for i, nodes in enumerate(self._epoch_nodes):
                if i in self._evaluated or not nodes:
                    continue
                if not all(n in self._llm_obs for n in nodes):
                    continue
                self._evaluated.add(i)
                obs = self._observed_epoch_cost(nodes)
                pred = self.plan.epochs[i].predicted_cost
                drift = abs(obs - pred) / max(pred, 1e-9)
                self.epoch_drifts.append(
                    {"epoch": i, "predicted": pred, "observed": obs,
                     "drift": drift})
                if drift > self.drift_threshold:
                    trigger = True
        if not trigger:
            return False
        return self._replan(board, migrator)

    def _replan(self, board: PlanBoard, migrator=None) -> bool:
        """Re-solve the unclaimed DAG from the live state and splice."""
        with board.lock:                          # one consistent snapshot
            done = frozenset(board.claimed_set)
            contexts = board.contexts_locked()
        if len(done) == len(self.dag.node_ids):
            return False                          # nothing left to replan
        with self.lock:                 # a graft may grow these mid-solve
            prios = dict(self.node_priorities)
        solver = EpochDPSolver(self.dag, self.cm, self.solver_config,
                               priorities=prios)
        tail = solver.solve(initial=SystemState(done, contexts))
        return self._apply_tail(board, tail, migrator)

    def _apply_tail(self, board: PlanBoard, tail: ExecutionPlan,
                    migrator=None) -> bool:
        """Validate ``tail`` against the live claimed prefix, migrate
        moved nodes' warm KV, and splice the tail into the board."""
        with board.lock:
            claimed = set(board.claimed_set)
            prefix = board.claimed_prefix_epochs_locked()
        if len(claimed) == len(self.dag.node_ids):
            return False                          # nothing left to move
        # drop nodes claimed since the tail was solved/queued (the board
        # would filter them anyway; validation must see each node once)
        epochs = []
        for e in tail.epochs:
            comps = [[n for n in comp if n not in claimed]
                     for comp in e.components]
            keep = [(c, w) for c, w in zip(comps, e.workers) if c]
            if keep:
                epochs.append(Epoch([c for c, _ in keep],
                                    [w for _, w in keep],
                                    e.predicted_cost))
        tail = ExecutionPlan(epochs, tail.predicted_cost,
                             scheduler_name=tail.scheduler_name)
        with self.lock:                 # attach_plan may swap the plan
            plan = self.plan
        base = (plan.scheduler_name if plan is not None else "") \
            or "halo-dp"
        spliced = ExecutionPlan(
            epochs=prefix + tail.epochs,
            predicted_cost=tail.predicted_cost,
            scheduler_name=base + "+replan")
        spliced.validate(self.dag)                # splice validity
        if migrator is not None:
            # migrate BEFORE publishing the new assignments: the moved
            # node's first wave on its new worker must find warm pages
            migrator.migrate_for_splice(board, tail)
        board.splice(tail)
        with self.lock:
            self.replans += 1
            self.spliced_plan = spliced
        self.attach_plan(spliced, fresh=False, evaluated_prefix=len(prefix))
        return True

    # ------------------------------------------------------- reporting
    def calibration_summary(self) -> Dict[str, float]:
        with self.lock:
            out = self.calib.deltas()
            out["tool_keys"] = self.cm.profiler.calibrated_keys()
            out["tool_observations"] = self.cm.profiler.observations
            if self.predicted_errors:
                out["first_llm_error"] = round(self.predicted_errors[0], 4)
                out["last_llm_error"] = round(self.predicted_errors[-1], 4)
            return out
