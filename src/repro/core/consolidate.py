"""Consolidation — a batch of N queries over one template → one graph.

Each template node becomes a MACRO-NODE carrying the N per-query
bindings (DESIGN.md §8.1).  The optimizer plans macro-nodes (the DP
state space is independent of N); the Processor batches the bindings
inside each epoch.

Physical request counts are derived by BINDING-INFLUENCE propagation:
node v's output is a deterministic function of the binding parameters
appearing in its own template plus (transitively) in its ancestors'.
Two queries whose bindings agree on that influence set are guaranteed to
produce identical requests at v — so they coalesce.  For tool nodes with
binding-only args the rendered string itself is the signature (letting
DIFFERENT nodes that issue the same SQL share one physical execution).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Sequence, Set

from repro.core.graphspec import GraphSpec, NodeSpec
from repro.core.parser import static_signature

_REF = re.compile(r"\$\{([A-Za-z_][A-Za-z0-9_]*)\}")
_PARAM = re.compile(r"\$([A-Za-z_][A-Za-z0-9_]*)")


def _template_params(text: str, binding_keys: Set[str]) -> Set[str]:
    """$params used directly in a template (excluding ${upstream} refs)."""
    no_refs = _REF.sub("", text)
    return {p for p in _PARAM.findall(no_refs) if p in binding_keys}


@dataclass
class MacroNode:
    spec: NodeSpec
    bindings: List[Dict[str, str]]
    # influence set: binding params that (transitively) shape this node
    influence: FrozenSet[str] = frozenset()
    # distinct physical request signatures + per-query mapping
    unique_signatures: List[str] = field(default_factory=list)
    signature_of_query: List[int] = field(default_factory=list)

    @property
    def n_logical(self) -> int:
        return len(self.bindings)

    @property
    def n_unique(self) -> int:
        return len(self.unique_signatures)


class ConsolidatedGraph:
    """Template GraphSpec × N bindings, with per-node macro views."""

    def __init__(self, template: GraphSpec,
                 bindings: Sequence[Dict[str, str]]):
        self.template = template
        self.bindings = [dict(b) for b in bindings]
        keys: Set[str] = set()
        for b in self.bindings:
            keys |= set(b)

        # ---- influence propagation (topological) ------------------------
        influence: Dict[str, Set[str]] = {}
        for nid in template.topo_order():
            spec = template.nodes[nid]
            text = spec.prompt if spec.is_llm() else spec.args
            inf = _template_params(text, keys)
            for p in template.parents(nid):
                inf |= influence[p]
            influence[nid] = inf

        # ---- per-node signatures ----------------------------------------
        self.macros: Dict[str, MacroNode] = {}
        for nid, spec in template.nodes.items():
            text = spec.prompt if spec.is_llm() else spec.args
            has_refs = bool(_REF.search(text))
            inf = sorted(influence[nid])
            sig_ix: Dict[str, int] = {}
            uniq: List[str] = []
            of_query: List[int] = []
            for b in self.bindings:
                if has_refs or spec.is_llm():
                    # upstream-dependent: influence-tuple signature
                    s = nid + "|" + "|".join(str(b.get(k, "")) for k in inf)
                else:
                    # binding-only tool args: the rendered string itself —
                    # different nodes issuing identical requests coalesce
                    s = spec.op + "|" + static_signature(text, b)
                if s not in sig_ix:
                    sig_ix[s] = len(uniq)
                    uniq.append(s)
                of_query.append(sig_ix[s])
            self.macros[nid] = MacroNode(
                spec=spec, bindings=self.bindings,
                influence=frozenset(influence[nid]),
                unique_signatures=uniq, signature_of_query=of_query)

    @property
    def n_queries(self) -> int:
        return len(self.bindings)

    def macro(self, nid: str) -> MacroNode:
        return self.macros[nid]

    def static_dedup_ratio(self, nid: str) -> float:
        """unique / logical — 1.0 means no cross-query redundancy."""
        m = self.macros[nid]
        return m.n_unique / max(m.n_logical, 1)

    def coalescing_summary(self) -> Dict[str, Dict[str, int]]:
        return {nid: {"logical": m.n_logical, "unique": m.n_unique}
                for nid, m in self.macros.items()}


def consolidate(template: GraphSpec,
                bindings: Sequence[Dict[str, str]]) -> ConsolidatedGraph:
    return ConsolidatedGraph(template, bindings)
