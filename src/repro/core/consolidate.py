"""Consolidation — a batch of queries over one or many templates → one graph.

Each template node becomes a MACRO-NODE carrying the per-query bindings
(DESIGN.md §8.1).  The optimizer plans macro-nodes (the DP state space
is independent of N); the Processor batches the bindings inside each
epoch.

Physical request counts are derived by BINDING-INFLUENCE propagation:
node v's output is a deterministic function of the binding parameters
appearing in its own template plus (transitively) in its ancestors'.
Two queries whose bindings agree on that influence set are guaranteed to
produce identical requests at v — so they coalesce.  For tool nodes with
binding-only args the rendered string itself is the signature (letting
DIFFERENT nodes that issue the same SQL share one physical execution).

``consolidate_multi`` extends this across templates (DESIGN.md §8.1):
a mixed batch — several (template, bindings) pairs — merges into ONE
mega-DAG whose node ids are namespaced per template (``t0/plan``,
``t1/gen``).  Binding influence propagates per template (the merged
graph is a disjoint union), but the signature space is shared, so two
DIFFERENT templates issuing the same rendered SQL coalesce into one
physical request, and LLM nodes with identical static prompts become
warm-KV aliases the cost model can credit across templates.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import (Dict, FrozenSet, List, Optional, Sequence, Set, Tuple)

from repro.core.graphspec import GraphSpec, NodeSpec
from repro.core.parser import static_signature

# upstream refs may carry a template namespace ("${t0/plan}"), hence "/"
_REF = re.compile(r"\$\{([A-Za-z_][A-Za-z0-9_/]*)\}")
_PARAM = re.compile(r"\$([A-Za-z_][A-Za-z0-9_]*)")


def _template_params(text: str, binding_keys: Set[str]) -> Set[str]:
    """$params used directly in a template (excluding ${upstream} refs)."""
    no_refs = _REF.sub("", text)
    return {p for p in _PARAM.findall(no_refs) if p in binding_keys}


def _influence_sets(template: GraphSpec,
                    binding_keys: Set[str]) -> Dict[str, Set[str]]:
    """Topological binding-influence propagation over one template."""
    influence: Dict[str, Set[str]] = {}
    for nid in template.topo_order():
        spec = template.nodes[nid]
        text = spec.prompt if spec.is_llm() else spec.args
        inf = _template_params(text, binding_keys)
        for p in template.parents(nid):
            inf |= influence[p]
        influence[nid] = inf
    return influence


def _node_signatures(spec: NodeSpec, sig_id: str,
                     influence_keys: Sequence[str],
                     bindings: Sequence[Dict[str, str]]
                     ) -> Tuple[List[str], List[int]]:
    """(unique signatures, per-query signature index) for one macro node.

    ``sig_id`` is the template-LOCAL node id (multi-template
    consolidation namespaces the graph ids but keeps signatures in the
    base id space so the same template submitted twice produces
    comparable signatures), optionally suffixed ``@<lineage digest>`` by
    the multi consolidator so nodes whose upstream subtrees differ can
    never share an upstream-dependent signature.
    """
    text = spec.prompt if spec.is_llm() else spec.args
    has_refs = bool(_REF.search(text))
    # spec identity disambiguates COLLIDING local ids across templates
    # (same "t" node in two unrelated templates must not merge) while
    # staying equal for two copies of the same template
    ident = f"{sig_id}|{spec.op}|{spec.model}|{text}"
    sig_ix: Dict[str, int] = {}
    uniq: List[str] = []
    of_query: List[int] = []
    for b in bindings:
        if has_refs or spec.is_llm():
            # upstream-dependent: influence-tuple signature
            s = ident + "||" + "|".join(str(b.get(k, ""))
                                        for k in influence_keys)
        else:
            # binding-only tool args: the rendered string itself —
            # different nodes issuing identical requests coalesce
            s = spec.op + "|" + static_signature(text, b)
        if s not in sig_ix:
            sig_ix[s] = len(uniq)
            uniq.append(s)
        of_query.append(sig_ix[s])
    return uniq, of_query


def _namespace_spec(spec: NodeSpec, id_map: Dict[str, str]) -> NodeSpec:
    """Rewrite a NodeSpec into the merged-graph namespace: its own id and
    every ``${upstream}`` ref it mentions get the template prefix."""
    def _sub(m: re.Match) -> str:
        return "${" + id_map.get(m.group(1), m.group(1)) + "}"

    return spec.with_(id=id_map[spec.id],
                      prompt=_REF.sub(_sub, spec.prompt),
                      args=_REF.sub(_sub, spec.args))


@dataclass
class MacroNode:
    """One template node × its queries' bindings (a planning unit)."""

    spec: NodeSpec
    bindings: List[Dict[str, str]]
    # influence set: binding params that (transitively) shape this node
    influence: FrozenSet[str] = frozenset()
    # distinct physical request signatures + per-query mapping
    unique_signatures: List[str] = field(default_factory=list)
    signature_of_query: List[int] = field(default_factory=list)
    # provenance: which template this node came from + the GLOBAL query
    # indices it serves (single-template: all of them)
    template: int = 0
    queries: Tuple[int, ...] = ()

    @property
    def n_logical(self) -> int:
        """Logical request count (one per query of this node's template)."""
        return len(self.bindings)

    @property
    def n_unique(self) -> int:
        """Distinct request signatures within this macro node."""
        return len(self.unique_signatures)


class ConsolidatedGraph:
    """Template GraphSpec × N bindings, with per-node macro views."""

    def __init__(self, template: GraphSpec,
                 bindings: Sequence[Dict[str, str]]):
        self.template = template
        self.bindings = [dict(b) for b in bindings]
        self.template_names = [template.name]
        self.template_of: Dict[str, int] = {nid: 0 for nid in template.nodes}
        keys: Set[str] = set()
        for b in self.bindings:
            keys |= set(b)
        influence = _influence_sets(template, keys)
        qs = tuple(range(len(self.bindings)))
        self.macros: Dict[str, MacroNode] = {}
        for nid, spec in template.nodes.items():
            uniq, of_query = _node_signatures(
                spec, nid, sorted(influence[nid]), self.bindings)
            self.macros[nid] = MacroNode(
                spec=spec, bindings=self.bindings,
                influence=frozenset(influence[nid]),
                unique_signatures=uniq, signature_of_query=of_query,
                template=0, queries=qs)

    # ------------------------------------------------------------------
    @property
    def n_queries(self) -> int:
        """Total queries across every template in the batch."""
        return len(self.bindings)

    @property
    def n_templates(self) -> int:
        """How many templates were consolidated (1 unless multi)."""
        return len(self.template_names)

    def macro(self, nid: str) -> MacroNode:
        """The macro-node view of template node ``nid``."""
        return self.macros[nid]

    def queries_map(self) -> Optional[Dict[str, List[int]]]:
        """Per-node global query indices, or None when every node serves
        every query (the single-template case — BatchState's default)."""
        return None

    def physical_signatures(self, nid: str) -> List[str]:
        """Signatures ``nid`` must physically execute.  Multi-template
        consolidation removes signatures another template's node already
        owns; single-template keeps every unique signature."""
        return list(self.macros[nid].unique_signatures)

    def warm_aliases(self) -> Dict[str, Tuple[str, ...]]:
        """LLM nodes whose warm KV is interchangeable with ``nid``'s
        (identical static prompts across templates); empty for single."""
        return {}

    def static_dedup_ratio(self, nid: str) -> float:
        """unique / logical — 1.0 means no cross-query redundancy.

        A macro-node can end up with ``n_logical == 0`` (a template
        submitted with an empty binding list, or every request merged
        away by cross-template consolidation): that is "no redundancy",
        not infinite dedup, so the ratio pins to 1.0 instead of
        dividing by zero.
        """
        m = self.macros[nid]
        if m.n_logical == 0:
            return 1.0
        return m.n_unique / m.n_logical

    def coalescing_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-node logical/unique/physical counts + guarded dedup ratio."""
        return {nid: {"logical": m.n_logical, "unique": m.n_unique,
                      "physical": len(self.physical_signatures(nid)),
                      "dedup_ratio": round(self.static_dedup_ratio(nid), 6)}
                for nid, m in self.macros.items()}

    def batch_sizes(self, logical_tools: bool = False) -> Dict[str, int]:
        """Per-node request counts for the cost model: LLM nodes price
        their logical batch (every query decodes); tool nodes price only
        the physical executions left after coalescing (or the logical
        count when ``logical_tools`` — the no-coalescing A/B control)."""
        return {nid: (m.n_logical if (m.spec.is_llm() or logical_tools)
                      else len(self.physical_signatures(nid)))
                for nid, m in self.macros.items()}


class MultiConsolidatedGraph(ConsolidatedGraph):
    """Several (template, bindings) pairs merged into one mega-DAG.

    Node ids are namespaced ``t{k}/{id}`` (so colliding ids across
    templates stay distinct), upstream refs inside prompts/args are
    rewritten to the namespaced form, and the global binding list is the
    concatenation of the per-template lists — macro node ``t{k}/v``
    serves exactly template k's global query slice.

    Signatures stay in the base id space, so the shared signature table
    dedups ACROSS templates: the first node (in merged topo order) to
    issue a signature owns its physical execution; later nodes —
    including nodes of other templates — alias it.  LLM nodes with
    identical static specs become ``warm_aliases`` for the cost model's
    cross-template prefix credit.
    """

    def __init__(self, batches: Sequence[Tuple[GraphSpec,
                                               Sequence[Dict[str, str]]]]):
        batches = list(batches)
        if not batches:
            raise ValueError("consolidate_multi needs at least one batch")
        # persistent merge state: graft() appends to these and re-derives
        # the views, so a session can keep consolidating into one graph
        self._nodes: List[NodeSpec] = []
        self._edges: List[Tuple[str, str]] = []
        self.bindings = []            # identity is shared with the runtime
        self.template_names = []
        self.template_of = {}
        self.macros = {}
        self._alias_key: Dict[str, str] = {}  # nid -> lineage digest
        self._owner: Dict[str, str] = {}
        self._absorb(batches)

    def _absorb(self, batches: Sequence[Tuple[GraphSpec,
                                              Sequence[Dict[str, str]]]]
                ) -> List[str]:
        """Merge ``batches`` into the persistent state (initial build and
        every later graft) and return the newly added node ids.

        Template indices and query offsets continue where the last absorb
        stopped; ``self.bindings`` is EXTENDED in place (the running
        dispatcher/workers hold a reference to it); signature ownership
        uses ``setdefault`` over the full merged topo order, so an
        already-owned signature keeps its owner — a grafted node whose
        request an in-flight (or finished) node already issued aliases
        that execution instead of re-running it (DESIGN.md §10.2).
        """
        nodes: List[NodeSpec] = list(self._nodes)
        edges: List[Tuple[str, str]] = list(self._edges)
        new_ids: List[str] = []
        alias_key = self._alias_key
        offset = len(self.bindings)
        for k, (tmpl, binds) in enumerate(batches,
                                          start=len(self.template_names)):
            ns = f"t{k}/"
            binds = [dict(b) for b in binds]
            keys: Set[str] = set()
            for b in binds:
                keys |= set(b)
            influence = _influence_sets(tmpl, keys)
            id_map = {nid: ns + nid for nid in tmpl.nodes}
            qs = tuple(range(offset, offset + len(binds)))
            # structural lineage digest: the node's own spec (id-free)
            # chained over its parents' digests — equal ONLY when the
            # whole upstream subtree is identical, so "Summarize ${x}"
            # over different x-templates never aliases or dedups.
            # Chaining (not nesting) keeps this O(nodes) on fan-in
            # heavy templates where a materialized subtree key would
            # blow up exponentially.
            lineage_digest: Dict[str, str] = {}
            for nid in tmpl.topo_order():
                spec = tmpl.nodes[nid]
                # parent order is the template's edge order — identical
                # for two copies of the same template, which is all the
                # equality needs
                payload = repr((spec.with_(id=""),
                                tuple(lineage_digest[p]
                                      for p in tmpl.parents(nid))))
                lineage_digest[nid] = hashlib.blake2b(
                    payload.encode(), digest_size=8).hexdigest()
            for nid, spec in tmpl.nodes.items():
                nspec = _namespace_spec(spec, id_map)
                nodes.append(nspec)
                new_ids.append(nspec.id)
                self.template_of[nspec.id] = k
                # the lineage digest keys upstream-dependent signatures:
                # requests dedup across templates ONLY when the whole
                # subtree feeding them is identical (two copies of one
                # template share digests; colliding ids or same-text
                # nodes over different parents do not)
                uniq, of_query = _node_signatures(
                    spec, f"{nid}@{lineage_digest[nid]}",
                    sorted(influence[nid]), binds)
                self.macros[nspec.id] = MacroNode(
                    spec=nspec, bindings=binds,
                    influence=frozenset(influence[nid]),
                    unique_signatures=uniq, signature_of_query=of_query,
                    template=k, queries=qs)
                if spec.is_llm():
                    # identity in the ORIGINAL template space: the whole
                    # upstream subtree must match for two nodes' warm KV
                    # to be interchangeable at the engine's radix tree
                    alias_key[nspec.id] = lineage_digest[nid]
            edges.extend((ns + u, ns + v) for u, v in tmpl.edges)
            self.template_names.append(tmpl.name)
            self.bindings.extend(binds)
            offset += len(binds)
        self._nodes, self._edges = nodes, edges
        self.template = GraphSpec(
            "multi(" + "+".join(self.template_names) + ")", nodes, edges)

        # ---- cross-template signature ownership (tool dedup) ------------
        # first issuer in merged topo order owns the physical execution;
        # setdefault never re-keys an existing signature, so grafts can't
        # move ownership off a node that may already have executed
        for nid in self.template.topo_order():
            m = self.macros[nid]
            if m.spec.is_llm():
                continue
            for s in m.unique_signatures:
                self._owner.setdefault(s, nid)

        # ---- warm-KV aliases across templates (LLM radix sharing) -------
        groups: Dict[str, List[str]] = {}
        for nid, key in alias_key.items():
            groups.setdefault(key, []).append(nid)
        self._aliases: Dict[str, Tuple[str, ...]] = {}
        for members in groups.values():
            if len(members) < 2:
                continue
            for nid in members:
                self._aliases[nid] = tuple(x for x in members if x != nid)
        return new_ids

    # ------------------------------------------------------------------
    def graft(self, batches: Sequence[Tuple[GraphSpec,
                                            Sequence[Dict[str, str]]]]
              ) -> Tuple[List[str], int]:
        """Incrementally consolidate ``batches`` into this mega-DAG
        (DESIGN.md §10.2).

        Returns ``(new_node_ids, query_offset)``: the namespaced ids the
        graft added and the global index of its first query.  The grafted
        nodes join the EXISTING signature table (a request an in-flight
        node already issued is aliased, not re-executed) and the existing
        warm-alias groups (the engine's radix tree shares their pages),
        which is what lets a query arriving mid-run ride on the running
        batch's work instead of waiting for the next one.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("graft needs at least one batch")
        query_offset = len(self.bindings)
        new_ids = self._absorb(batches)
        return new_ids, query_offset

    # ------------------------------------------------------------------
    def queries_map(self) -> Optional[Dict[str, List[int]]]:
        """Each namespaced node serves only its own template's slice."""
        return {nid: list(m.queries) for nid, m in self.macros.items()}

    def physical_signatures(self, nid: str) -> List[str]:
        """Signatures ``nid`` owns — the rest ride on another template's
        (or an earlier node's) physical execution."""
        m = self.macros[nid]
        if m.spec.is_llm():
            return list(m.unique_signatures)
        return [s for s in m.unique_signatures if self._owner[s] == nid]

    def warm_aliases(self) -> Dict[str, Tuple[str, ...]]:
        """nid → other LLM nodes with the identical static spec."""
        return dict(self._aliases)

    def cross_template_summary(self) -> Dict[str, float]:
        """How much the mega-DAG coalesced ACROSS template boundaries."""
        sig_templates: Dict[str, Set[int]] = {}
        tool_unique = tool_physical = cross_deduped = 0
        for nid, m in self.macros.items():
            if m.spec.is_llm():
                continue
            tool_unique += m.n_unique
            owned = 0
            for s in m.unique_signatures:
                sig_templates.setdefault(s, set()).add(m.template)
                own = self._owner[s]
                if own == nid:
                    owned += 1
                elif self.macros[own].template != m.template:
                    cross_deduped += 1
            tool_physical += owned
        merged = sum(1 for ts in sig_templates.values() if len(ts) >= 2)
        return {
            "templates": self.n_templates,
            "tool_unique": tool_unique,
            "tool_physical": tool_physical,
            "deduped_requests": tool_unique - tool_physical,
            "cross_template_deduped": cross_deduped,
            "merged_signatures": merged,
            "llm_alias_nodes": len(self._aliases),
        }


def consolidate(template: GraphSpec,
                bindings: Sequence[Dict[str, str]]) -> ConsolidatedGraph:
    """One template × N bindings → one consolidated graph."""
    return ConsolidatedGraph(template, bindings)


def consolidate_multi(batches: Sequence[Tuple[GraphSpec,
                                              Sequence[Dict[str, str]]]]
                      ) -> MultiConsolidatedGraph:
    """Many (template, bindings) pairs → one consolidated mega-DAG.

    The merged graph namespaces node ids per template and shares one
    signature table, so redundant requests coalesce across templates and
    the epoch DP can interleave heterogeneous macro-nodes in one epoch
    (DESIGN.md §8.1).
    """
    return MultiConsolidatedGraph(batches)
