"""System state for the epoch DP: S_e = (D_e, H_e)  (paper §4).

``WorkerContext`` is the persistent GPU-worker context h_w: the resident
model id and a compact warm-KV signature — the ordered tuple of the most
recent LLM node ids whose lineage is warm on that worker.  Both are
hashable so (D, H) keys the memo table.

``SLOClass`` is the per-request service lane (DESIGN.md §10.3): session
``submit()`` tags each query interactive or batch, the solver holds a
priority-weighted flow-time objective, and engine admission prefers the
higher class under KV-pool pressure.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Optional, Sequence, Tuple

# Compact representation: keep only the most recent K lineage ids.  K=2
# keeps the DP state space tractable (prefix discounts look one hop back:
# a node's parent lineage) — raising K grows states combinatorially for
# little planning value.
WARM_CAP = 2


@dataclass(frozen=True)
class WorkerContext:
    """Persistent per-worker GPU context h_w (resident model, warm KV)."""

    model: str = ""                               # resident weights m_w
    warm: Tuple[str, ...] = ()                    # kv signature u_w (recent-last)

    def after(self, node_id: str, node_model: str) -> "WorkerContext":
        """Deterministic transition after executing ``node_id``."""
        if node_model != self.model:
            return WorkerContext(model=node_model, warm=(node_id,))
        warm = tuple(w for w in self.warm if w != node_id) + (node_id,)
        return WorkerContext(model=self.model, warm=warm[-WARM_CAP:])

    def has_warm(self, node_id: str) -> bool:
        """True when ``node_id``'s lineage is warm in this context."""
        return node_id in self.warm

    def warm_parent(self, parents: Sequence[str]) -> Optional[str]:
        """First of ``parents`` whose lineage is warm in this context —
        the donor the prefix discount keys off.  With cross-worker KV
        migration, a PEER context's warm parent is also a valid donor
        (its pages can ship over the link), so the cost model probes
        this on every worker, not just the assignee."""
        for u in parents:
            if u in self.warm:
                return u
        return None


@dataclass(frozen=True)
class SLOClass:
    """A service lane for session submissions (DESIGN.md §10.3).

    ``priority`` orders lanes: a pending higher-priority request wins
    engine admission and weights the solver toward finishing its nodes
    early.  ``ttft_target_s`` / ``tpot_target_s`` are the lane's latency
    targets — reported against, never enforced by dropping work.
    """

    name: str
    priority: int = 0
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None


#: latency-sensitive lane: preempts batch admission, never vice versa
INTERACTIVE = SLOClass("interactive", priority=1,
                       ttft_target_s=1.0, tpot_target_s=0.25)
#: throughput lane: the default for bulk analytics submissions
BATCH = SLOClass("batch", priority=0)

SLO_CLASSES: Dict[str, SLOClass] = {c.name: c for c in (INTERACTIVE, BATCH)}


@dataclass(frozen=True)
class SystemState:
    """DP state S = (completed LLM set, per-worker contexts)."""

    done: FrozenSet[str] = frozenset()
    contexts: Tuple[WorkerContext, ...] = ()

    def key(self) -> Tuple:
        """Hashable memo key."""
        return (self.done, self.contexts)

    @staticmethod
    def initial(num_workers: int) -> "SystemState":
        """The empty starting state for ``num_workers`` cold workers."""
        return SystemState(frozenset(),
                           tuple(WorkerContext() for _ in range(num_workers)))
