"""System state for the epoch DP: S_e = (D_e, H_e)  (paper §4).

``WorkerContext`` is the persistent GPU-worker context h_w: the resident
model id and a compact warm-KV signature — the ordered tuple of the most
recent LLM node ids whose lineage is warm on that worker.  Both are
hashable so (D, H) keys the memo table.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Optional, Sequence, Tuple

# Compact representation: keep only the most recent K lineage ids.  K=2
# keeps the DP state space tractable (prefix discounts look one hop back:
# a node's parent lineage) — raising K grows states combinatorially for
# little planning value.
WARM_CAP = 2


@dataclass(frozen=True)
class WorkerContext:
    """Persistent per-worker GPU context h_w (resident model, warm KV)."""

    model: str = ""                               # resident weights m_w
    warm: Tuple[str, ...] = ()                    # kv signature u_w (recent-last)

    def after(self, node_id: str, node_model: str) -> "WorkerContext":
        """Deterministic transition after executing ``node_id``."""
        if node_model != self.model:
            return WorkerContext(model=node_model, warm=(node_id,))
        warm = tuple(w for w in self.warm if w != node_id) + (node_id,)
        return WorkerContext(model=self.model, warm=warm[-WARM_CAP:])

    def has_warm(self, node_id: str) -> bool:
        """True when ``node_id``'s lineage is warm in this context."""
        return node_id in self.warm

    def warm_parent(self, parents: Sequence[str]) -> Optional[str]:
        """First of ``parents`` whose lineage is warm in this context —
        the donor the prefix discount keys off.  With cross-worker KV
        migration, a PEER context's warm parent is also a valid donor
        (its pages can ship over the link), so the cost model probes
        this on every worker, not just the assignee."""
        for u in parents:
            if u in self.warm:
                return u
        return None


@dataclass(frozen=True)
class SystemState:
    """DP state S = (completed LLM set, per-worker contexts)."""

    done: FrozenSet[str] = frozenset()
    contexts: Tuple[WorkerContext, ...] = ()

    def key(self) -> Tuple:
        """Hashable memo key."""
        return (self.done, self.contexts)

    @staticmethod
    def initial(num_workers: int) -> "SystemState":
        """The empty starting state for ``num_workers`` cold workers."""
        return SystemState(frozenset(),
                           tuple(WorkerContext() for _ in range(num_workers)))
