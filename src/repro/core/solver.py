"""Epoch-based dynamic-programming solver (Algorithm 1; DESIGN.md §8.3).

Memoized recursion over states S = (D, H): D the completed LLM set, H
the tuple of worker contexts.  Each step enumerates feasible epoch
actions — topological cuts of the LLM DAG partitioned into chains
(weakly-connected components executed sequentially on one worker) and
injective chain→worker maps — scores them with the state-aware cost
model, and recurses on the deterministic state transition.

State-space control (the paper's "pruning to topological frontiers"):
* candidate nodes = frontier closure up to ``chain_depth`` levels, so
  dependent steps can chain inside one epoch (model residency + warm KV);
* subsets capped at ``max_epoch_nodes``;
* chain→worker assignments deduped by worker-context equivalence classes
  (two idle identical workers are interchangeable).
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.cost_model import CostModel
from repro.core.graphspec import LLMDag
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.state import SystemState, WorkerContext


@dataclass
class SolverConfig:
    """EpochDPSolver knobs (workers, frontier depth, caps, beam)."""

    num_workers: int = 3
    chain_depth: int = 2           # frontier closure levels per epoch
    max_epoch_nodes: int = 6       # |B_e| cap
    max_states: int = 200_000      # hard safety valve on memo size
    # weight on the priority-hold term: each epoch's cost is scaled by
    # 1 + priority_hold * (pending priority mass), so time spent while
    # interactive-class nodes wait costs more than the same time after
    # they finish — priority-weighted flow time (DESIGN.md §10.3).
    # With no priorities the objective reduces to plain makespan.
    priority_hold: float = 0.5
    # beam over epoch actions per state, ranked by immediate cost with a
    # work-density tie-break; None = exact enumeration.  This is the
    # "pruning to topological frontiers" knob that keeps planning
    # near-linear in practice (§4, complexity analysis).
    beam: Optional[int] = 16


class EpochDPSolver:
    """Algorithm 1: memoized epoch DP over (done, contexts) states."""

    def __init__(self, dag: LLMDag, cost_model: CostModel,
                 config: Optional[SolverConfig] = None,
                 priorities: Optional[Dict[str, float]] = None):
        self.dag = dag
        self.cm = cost_model
        # fresh instance per solver: a module-level default would be one
        # shared mutable object across every EpochDPSolver in the process
        self.cfg = config if config is not None else SolverConfig()
        # per-node SLO priority mass (DESIGN.md §10.3): only nodes still
        # pending hold the objective, so the DP front-loads them.  Empty
        # or all-zero priorities leave every plan bitwise unchanged.
        self.prio = {n: w for n, w in (priorities or {}).items()
                     if w and n in dag.node_ids}
        self.memo: Dict[Tuple, Tuple[float, Optional[Tuple]]] = {}
        self.states_explored = 0

    # ------------------------------------------------------------------
    def _candidates(self, done: FrozenSet[str]) -> List[str]:
        """Frontier closure: nodes launchable this epoch (chains allowed)."""
        cand: List[str] = []
        d = set(done)
        for _ in range(self.cfg.chain_depth):
            level = [v for v in self.dag.frontier(frozenset(d)) if v not in cand]
            if not level:
                break
            cand.extend(level)
            d.update(level)
        return cand

    def _batches(self, done: FrozenSet[str]) -> List[FrozenSet[str]]:
        cand = self._candidates(done)
        out: List[FrozenSet[str]] = []
        max_n = min(len(cand), self.cfg.max_epoch_nodes)
        for r in range(1, max_n + 1):
            for sub in itertools.combinations(cand, r):
                batch = frozenset(sub)
                if not self.dag.is_valid_cut(done, batch):
                    continue
                comps = self.dag.components(batch)
                if len(comps) > self.cfg.num_workers:
                    continue
                out.append(batch)
        return out

    def _assignments(self, comps: List[List[str]],
                     contexts: Tuple[WorkerContext, ...]
                     ) -> List[Tuple[int, ...]]:
        """Injective component→worker maps, deduped by context classes."""
        W = len(contexts)
        # equivalence classes of workers by context
        cls: Dict[WorkerContext, List[int]] = {}
        for w, c in enumerate(contexts):
            cls.setdefault(c, []).append(w)
        reps = {w: cls[contexts[w]][0] for w in range(W)}
        seen: set = set()
        out: List[Tuple[int, ...]] = []
        for perm in itertools.permutations(range(W), len(comps)):
            key = tuple(reps[w] for w in perm)
            if key in seen:
                continue
            seen.add(key)
            out.append(perm)
        return out

    # ------------------------------------------------------------------
    def _solve(self, state: SystemState) -> Tuple[float, Optional[Tuple]]:
        if len(state.done) == len(self.dag.node_ids):
            return 0.0, None
        key = state.key()
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.states_explored += 1
        if self.states_explored > self.cfg.max_states:
            raise RuntimeError("DP state budget exceeded; raise max_states "
                               "or lower chain_depth/max_epoch_nodes")

        # enumerate candidate actions, score the immediate epoch cost
        actions = []
        for batch in self._batches(state.done):
            comps = self.dag.components(batch)
            for workers in self._assignments(comps, state.contexts):
                c_now, ctxs, _ = self.cm.epoch_cost(comps, workers, state)
                # rank by cost per unit of work (prefer dense epochs)
                rank = c_now / max(len(batch), 1)
                actions.append((rank, c_now, comps, workers, ctxs, batch))
        actions.sort(key=lambda a: a[0])
        if self.cfg.beam is not None:
            actions = actions[:self.cfg.beam]

        # priority hold: epoch time is weighted by the priority mass
        # still pending BEFORE the epoch runs, so plans that clear
        # interactive-class nodes early score better (weighted flow
        # time).  hold == 1.0 exactly when no priorities are set, which
        # keeps batch-only plans bitwise identical to the unweighted DP.
        hold = 1.0
        if self.prio:
            hold += self.cfg.priority_hold * sum(
                w for n, w in self.prio.items() if n not in state.done)

        best = (float("inf"), None)
        for _, c_now, comps, workers, ctxs, batch in actions:
            nxt = SystemState(state.done | batch, ctxs)
            c_fut, _ = self._solve(nxt)
            total = c_now * hold + c_fut
            if total < best[0]:
                best = (total, (tuple(map(tuple, comps)),
                                tuple(workers), c_now, nxt))
        self.memo[key] = best
        return best

    # ------------------------------------------------------------------
    def solve(self, initial: Optional[SystemState] = None) -> ExecutionPlan:
        """Solve from ``initial`` (or cold start) and rebuild the plan."""
        t0 = time.perf_counter()
        state = initial or SystemState.initial(self.cfg.num_workers)
        start_done = state.done
        total, _ = self._solve(state)
        # plan reconstruction from the memo chain
        plan = ExecutionPlan(predicted_cost=total, scheduler_name="halo-dp")
        while len(state.done) < len(self.dag.node_ids):
            _, step = self.memo[state.key()]
            assert step is not None
            comps, workers, c_now, nxt = step
            plan.epochs.append(Epoch([list(c) for c in comps],
                                     list(workers), c_now))
            state = nxt
        plan.solver_seconds = time.perf_counter() - t0
        plan.validate(self.dag, start_done)
        return plan
