"""Baseline schedulers (§6.3): Random, Round-Robin (Ray-style), HEFT,
plus the OpWise stage-synchronous executor (§6.1 baselines).

All emit the same ExecutionPlan format as the DP solver so the
Processor, simulator and Opt(S) metric treat them uniformly.
"""
from __future__ import annotations

import random
import time
from typing import Dict, List

from repro.core.cost_model import CostModel
from repro.core.graphspec import LLMDag
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.state import SystemState, WorkerContext


# ---------------------------------------------------------------------------
def random_plan(dag: LLMDag, cm: CostModel, num_workers: int,
                seed: int = 0) -> ExecutionPlan:
    """Dispatch ready nodes uniformly at random to random workers."""
    t0 = time.perf_counter()
    rng = random.Random(seed)
    state = SystemState.initial(num_workers)
    plan = ExecutionPlan(scheduler_name="random")
    total = 0.0
    while len(state.done) < len(dag.node_ids):
        frontier = dag.frontier(state.done)
        k = rng.randint(1, min(len(frontier), num_workers))
        batch = rng.sample(sorted(frontier), k)
        workers = rng.sample(range(num_workers), k)
        comps = [[v] for v in batch]
        c, ctxs, _ = cm.epoch_cost(comps, workers, state)
        plan.epochs.append(Epoch(comps, list(workers), c))
        total += c
        state = SystemState(state.done | frozenset(batch), ctxs)
    plan.predicted_cost = total
    plan.solver_seconds = time.perf_counter() - t0
    plan.validate(dag)
    return plan


# ---------------------------------------------------------------------------
def round_robin_plan(dag: LLMDag, cm: CostModel,
                     num_workers: int) -> ExecutionPlan:
    """RayServe-style decentralized round-robin over ready operators."""
    t0 = time.perf_counter()
    state = SystemState.initial(num_workers)
    plan = ExecutionPlan(scheduler_name="rr")
    total = 0.0
    next_w = 0
    while len(state.done) < len(dag.node_ids):
        frontier = dag.frontier(state.done)
        batch = frontier[:num_workers]
        workers = [(next_w + i) % num_workers for i in range(len(batch))]
        next_w = (next_w + len(batch)) % num_workers
        comps = [[v] for v in batch]
        c, ctxs, _ = cm.epoch_cost(comps, workers, state)
        plan.epochs.append(Epoch(comps, workers, c))
        total += c
        state = SystemState(state.done | frozenset(batch), ctxs)
    plan.predicted_cost = total
    plan.solver_seconds = time.perf_counter() - t0
    plan.validate(dag)
    return plan


# ---------------------------------------------------------------------------
def heft_plan(dag: LLMDag, cm: CostModel, num_workers: int) -> ExecutionPlan:
    """HEFT: upward-rank priority + greedy earliest-finish-time placement.

    Continuous-time greedy; converted to epochs afterwards (each HEFT
    "wave" of simultaneously-startable nodes becomes one epoch).  Greedy
    EFT accounts for worker state (model residency) when estimating costs,
    but — unlike the DP — never looks ahead.
    """
    t0 = time.perf_counter()

    # upward ranks with mean execution cost over a fresh-context worker
    fresh = WorkerContext()
    mean_cost = {v: cm.t_node(v, fresh, frozenset())[0] for v in dag.node_ids}
    rank: Dict[str, float] = {}

    def _upward(v: str) -> float:
        if v in rank:
            return rank[v]
        succ = dag.children(v)
        rank[v] = mean_cost[v] + (max(_upward(s) for s in succ)
                                  if succ else 0.0)
        return rank[v]

    for v in dag.node_ids:
        _upward(v)
    order = sorted(dag.node_ids, key=lambda v: -rank[v])

    ready_time = [0.0] * num_workers
    ctxs: List[WorkerContext] = [WorkerContext() for _ in range(num_workers)]
    finish: Dict[str, float] = {}
    assign: Dict[str, int] = {}
    start: Dict[str, float] = {}
    done: set = set()

    for v in order:
        best = (float("inf"), -1, 0.0, None)
        dep_ready = max((finish[p] for p in dag.parents(v)), default=0.0)
        for w in range(num_workers):
            t, nctx = cm.t_node(v, ctxs[w], frozenset(done))
            st = max(ready_time[w], dep_ready)
            eft = st + t
            if eft < best[0]:
                best = (eft, w, st, nctx)
        eft, w, st, nctx = best
        assign[v], start[v], finish[v] = w, st, eft
        ready_time[w] = eft
        ctxs[w] = nctx
        done.add(v)

    plan = _continuous_to_plan(dag, cm, num_workers, assign, start,
                               "heft")
    plan.solver_seconds = time.perf_counter() - t0
    return plan


def _continuous_to_plan(dag: LLMDag, cm: CostModel, num_workers: int,
                        assign: Dict[str, int], start: Dict[str, float],
                        name: str) -> ExecutionPlan:
    """Convert a continuous-time schedule into precedence-valid epochs."""
    plan = ExecutionPlan(scheduler_name=name)
    state = SystemState.initial(num_workers)
    remaining = sorted(dag.node_ids, key=lambda v: start[v])
    total = 0.0
    while remaining:
        used: set = set()
        comps: List[List[str]] = []
        workers: List[int] = []
        taken: List[str] = []
        for v in remaining:
            w = assign[v]
            if w in used:
                continue
            if all(p in state.done for p in dag.parents(v)):
                comps.append([v])
                workers.append(w)
                used.add(w)
                taken.append(v)
        c, ctxs, _ = cm.epoch_cost(comps, workers, state)
        total += c
        plan.epochs.append(Epoch(comps, workers, c))
        state = SystemState(state.done | frozenset(taken), ctxs)
        remaining = [v for v in remaining if v not in taken]
    plan.predicted_cost = total
    plan.validate(dag)
    return plan


# ---------------------------------------------------------------------------
def opwise_plan(dag: LLMDag, cm: CostModel, num_workers: int) -> ExecutionPlan:
    """OpWise: strict stage-wise (MapReduce/Spark-style) execution.

    All nodes of one topological level run as one maximal batch with a
    barrier before the next level — maximizing instantaneous batch size
    but forbidding cross-stage interleaving (the straggler/model-thrash
    pathology the paper measures).
    """
    t0 = time.perf_counter()
    level: Dict[str, int] = {}
    for v in dag.graph.topo_order():
        if v not in dag.node_ids:
            continue
        ps = dag.parents(v)
        level[v] = 1 + max((level[p] for p in ps), default=-1)
    n_levels = max(level.values()) + 1

    plan = ExecutionPlan(scheduler_name="opwise")
    state = SystemState.initial(num_workers)
    total = 0.0
    for lv in range(n_levels):
        nodes = [v for v in dag.node_ids if level[v] == lv]
        # one epoch per ceil(len/num_workers) wave, round-robin workers
        for i0 in range(0, len(nodes), num_workers):
            wave = nodes[i0:i0 + num_workers]
            comps = [[v] for v in wave]
            workers = list(range(len(wave)))
            c, ctxs, _ = cm.epoch_cost(comps, workers, state)
            total += c
            plan.epochs.append(Epoch(comps, workers, c))
            state = SystemState(state.done | frozenset(wave), ctxs)
    plan.predicted_cost = total
    plan.solver_seconds = time.perf_counter() - t0
    plan.validate(dag)
    return plan


SCHEDULERS = {
    "random": random_plan,
    "rr": round_robin_plan,
    "heft": heft_plan,
    "opwise": opwise_plan,
}
