"""Continuous-time oracle (§6.3) — exact branch-and-bound scheduler.

The paper's oracle is a continuous-time MILP (Gurobi-class).  Offline
here, we implement the equivalent exact search directly: branch over
(ready node → worker) decisions in event order, bound with the
remaining-critical-path lower bound, and return the makespan-optimal
schedule.  Exponential — intended for the small W1/W6-scale instances
of Table 4, where the MILP itself needs hours.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import CostModel
from repro.core.graphspec import LLMDag
from repro.core.plan import ExecutionPlan
from repro.core.schedulers import _continuous_to_plan
from repro.core.state import WorkerContext


@dataclass
class OracleResult:
    """The oracle's makespan-optimal schedule + search statistics."""

    makespan: float
    assign: Dict[str, int]
    start: Dict[str, float]
    plan: ExecutionPlan
    solver_seconds: float
    nodes_expanded: int


class BranchAndBoundOracle:
    """Exact (exponential) branch-and-bound scheduler — the Opt(S) ref."""

    def __init__(self, dag: LLMDag, cm: CostModel, num_workers: int,
                 time_limit: float = 120.0):
        self.dag = dag
        self.cm = cm
        self.W = num_workers
        self.time_limit = time_limit
        self.best = float("inf")
        self.best_sched: Optional[Tuple[Dict[str, int], Dict[str, float]]] = None
        self.expanded = 0
        self._t0 = 0.0
        # critical-path LOWER bounds: each node costed optimistically
        # (model already resident, parent lineage warm, prep overlapped) —
        # an admissible bound; fresh-context costs would over-prune
        self._cost: Dict[str, float] = {}
        for v in dag.node_ids:
            spec = dag.spec(v)
            warm_ctx = WorkerContext(model=spec.model,
                                     warm=tuple(dag.parents(v))[-2:])
            self._cost[v] = cm.t_infer(spec, warm_ctx, dag.parents(v))
        self._cp: Dict[str, float] = {}
        topo_llm = [v for v in dag.graph.topo_order() if v in set(dag.node_ids)]
        for v in reversed(topo_llm):
            succ = dag.children(v)
            self._cp[v] = self._cost[v] + (
                max(self._cp[s] for s in succ) if succ else 0.0)

    # ------------------------------------------------------------------
    def _branch(self, done: frozenset, finish: Dict[str, float],
                ready_time: List[float], ctxs: List[WorkerContext],
                assign: Dict[str, int], start: Dict[str, float],
                elapsed_max: float) -> None:
        self.expanded += 1
        if time.perf_counter() - self._t0 > self.time_limit:
            return
        if len(done) == len(self.dag.node_ids):
            if elapsed_max < self.best:
                self.best = elapsed_max
                self.best_sched = (dict(assign), dict(start))
            return
        frontier = self.dag.frontier(done)
        # lower bound: some pending node's critical path must still run
        lb = max(min(ready_time) + min(self._cp[v] for v in frontier),
                 elapsed_max)
        if lb >= self.best:
            return
        # branch on (node, worker); order workers by readiness for pruning
        for v in sorted(frontier, key=lambda x: -self._cp[x]):
            dep_ready = max((finish[p] for p in self.dag.parents(v)),
                            default=0.0)
            tried: set = set()
            for w in sorted(range(self.W), key=lambda w: ready_time[w]):
                ctx_key = (ctxs[w], round(max(ready_time[w], dep_ready), 9))
                if ctx_key in tried:          # symmetric worker pruning
                    continue
                tried.add(ctx_key)
                t, nctx = self.cm.t_node(v, ctxs[w], done)
                st = max(ready_time[w], dep_ready)
                ft = st + t
                if ft + (self._cp[v] - self._cost[v]) >= self.best:
                    continue
                assign[v], start[v], finish[v] = w, st, ft
                old_rt, old_ctx = ready_time[w], ctxs[w]
                ready_time[w], ctxs[w] = ft, nctx
                self._branch(done | {v}, finish, ready_time, ctxs,
                             assign, start, max(elapsed_max, ft))
                ready_time[w], ctxs[w] = old_rt, old_ctx
                del assign[v], start[v], finish[v]

    # ------------------------------------------------------------------
    def solve(self) -> OracleResult:
        """Exhaustive search (within time_limit) for the optimal plan."""
        self._t0 = time.perf_counter()
        self._branch(frozenset(), {}, [0.0] * self.W,
                     [WorkerContext() for _ in range(self.W)], {}, {}, 0.0)
        assert self.best_sched is not None, "oracle found no schedule"
        assign, start = self.best_sched
        plan = _continuous_to_plan(self.dag, self.cm, self.W, assign, start,
                                   "oracle")
        dt = time.perf_counter() - self._t0
        plan.solver_seconds = dt
        return OracleResult(self.best, assign, start, plan, dt, self.expanded)
