"""Request coalescing — canonical signatures + runtime merge table (§5).

Two layers:
* canonical_signature() normalizes an operator invocation (type + args)
  so logically identical requests map to one key — whitespace/case
  normalization for SQL, sorted query params for HTTP, stripped args for
  local functions;
* CoalesceTable merges PENDING tasks with equal signatures into one
  physical execution and fans the result out to all logical requesters.
  Used by the Processor at runtime (handles args that only materialize
  once upstream results arrive).

Coalescing is semantics-preserving by construction: only bit-identical
canonical signatures merge, so one physical run is equivalent to each
logical run.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_WS = re.compile(r"\s+")
_SQL_KW = re.compile(
    r"\b(select|from|where|group by|order by|join|on|and|or|limit|as|"
    r"having|inner|left|right|outer|count|sum|avg|min|max|distinct)\b",
    re.I)


def _normalize_sql(sql: str) -> str:
    s = _WS.sub(" ", sql).strip().rstrip(";").strip()
    return _SQL_KW.sub(lambda m: m.group(0).upper(), s)


def _normalize_http(args: str) -> str:
    s = _WS.sub(" ", args).strip()
    if "?" in s:
        base, _, qs = s.partition("?")
        params = sorted(p for p in qs.split("&") if p)
        s = base + "?" + "&".join(params)
    return s


def canonical_signature(op: str, args: str, model: str = "",
                        extra: str = "") -> str:
    """Normalized identity of one operator invocation (the merge key)."""
    if op == "sql":
        body = _normalize_sql(args)
    elif op == "http":
        body = _normalize_http(args)
    else:
        body = _WS.sub(" ", args).strip()
    payload = f"{op}|{model}|{body}|{extra}"
    return hashlib.blake2b(payload.encode(), digest_size=12).hexdigest()


@dataclass
class PhysicalTask:
    """One physical tool execution and the logical requests riding it."""

    signature: str
    op: str
    args: str
    # logical requesters: (query_id, node_id) pairs waiting for the result
    requesters: List[Tuple[int, str]] = field(default_factory=list)
    result: Optional[object] = None
    done: bool = False


class CoalesceTable:  # requires: BatchState.lock
    """Merge map from logical requests to physical executions.

    Thread contract: every method (and every direct read of the table's
    maps/counters) runs under the owning ``BatchState.lock`` — the tool
    dispatcher, the pool's ``_execute`` threads and the session's
    reporting all serialize on it (DESIGN.md §11).
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.pending: Dict[str, PhysicalTask] = {}      # guarded-by: BatchState.lock
        self.completed: Dict[str, PhysicalTask] = {}    # guarded-by: BatchState.lock
        # stats
        self.logical_requests = 0           # guarded-by: BatchState.lock
        self.physical_executions = 0        # guarded-by: BatchState.lock
        self.result_cache_hits = 0          # guarded-by: BatchState.lock

    def register(self, op: str, args: str, requester: Tuple[int, str],
                 model: str = "") -> Tuple[str, bool, Optional[object]]:
        """Returns (signature, needs_execution, cached_result)."""
        self.logical_requests += 1
        sig = canonical_signature(op, args, model)
        if not self.enabled:
            # every logical request becomes its own physical execution
            sig = f"{sig}#{self.logical_requests}"
            self.pending[sig] = PhysicalTask(sig, op, args, [requester])
            self.physical_executions += 1
            return sig, True, None
        if sig in self.completed:                  # reuse of finished result
            task = self.completed[sig]
            # keep attributing logical requesters after completion: the
            # cross-template merge stats read them (a late template
            # hitting an earlier template's cached result IS a merge)
            task.requesters.append(requester)
            self.result_cache_hits += 1
            return sig, False, task.result
        if sig in self.pending:                    # merge into in-flight task
            self.pending[sig].requesters.append(requester)
            return sig, False, None
        self.pending[sig] = PhysicalTask(sig, op, args, [requester])
        self.physical_executions += 1
        return sig, True, None

    def complete(self, sig: str, result: object) -> List[Tuple[int, str]]:
        """Mark physical task done; returns all logical requesters."""
        task = self.pending.pop(sig)
        task.result = result
        task.done = True
        self.completed[sig] = task
        return list(task.requesters)

    @property
    def dedup_ratio(self) -> float:
        """physical / logical — 1.0 means nothing merged."""
        return self.physical_executions / max(self.logical_requests, 1)
