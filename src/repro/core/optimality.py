"""Opt(S) — GPU-locality optimality metric (§6.3).

P(S) = ordered pairs of consecutive nodes executing on the same GPU
(tagged with the GPU).  Opt(S) = max over worker permutations π of
|P(S) ∩ π(P(S*))| / |P(S*)| — the recall of the oracle's co-location
decisions, invariant to worker relabeling.
"""
from __future__ import annotations

import itertools
from typing import Set, Tuple

from repro.core.plan import ExecutionPlan

Pair = Tuple[str, str, int]


def consecutive_pairs(plan: ExecutionPlan, num_workers: int) -> Set[Pair]:
    """P(S): (prev, next, worker) pairs of consecutive same-GPU nodes."""
    out: Set[Pair] = set()
    for w, seq in enumerate(plan.worker_sequences(num_workers)):
        for a, b in zip(seq, seq[1:]):
            out.add((a, b, w))
    return out


def optimality_score(plan: ExecutionPlan, oracle_plan: ExecutionPlan,
                     num_workers: int) -> float:
    """Opt(S): recall of the oracle's co-location decisions (§6.3)."""
    p_s = consecutive_pairs(plan, num_workers)
    p_star = consecutive_pairs(oracle_plan, num_workers)
    if not p_star:
        # the oracle never co-locates consecutively; degenerate — score by
        # matching the (empty) set exactly
        return 1.0 if not p_s else 0.0
    best = 0.0
    for perm in itertools.permutations(range(num_workers)):
        mapped = {(a, b, perm[w]) for a, b, w in p_star}
        best = max(best, len(p_s & mapped) / len(p_star))
    return best
