"""Parser — declarative workflows → GraphSpec (paper §3).

The key transformation is *dependency decoupling*: tool calls embedded in
LLM prompts (``{{sql: SELECT ...}}``, ``{{http: GET ...}}``,
``{{fn: name(...)}}``) are extracted into standalone TOOL nodes so the
scheduler sees them as schedulable units rather than opaque side-effects.
The directive in the prompt is replaced by a ``${tool_node_id}``
placeholder and a tool→llm edge is added.

Input format: a plain dict (JSON-compatible; the YAML of the paper maps
1:1 onto this):

    {"name": "w1",
     "nodes": [
       {"id": "search", "type": "llm", "model": "qwen3-14b",
        "prompt": "Summarize {{sql: SELECT r FROM rev WHERE m='$market'}}",
        "max_new_tokens": 32},
       {"id": "edit", "type": "llm", "model": "qwen3-32b",
        "prompt": "Refine ${search} for $market"},
     ],
     "edges": [["search", "edit"]]}       # optional; ${refs} add implicit edges
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.core.graphspec import GraphSpec, NodeSpec, NodeType

_DIRECTIVE = re.compile(r"\{\{\s*(sql|http|fn)\s*:\s*(.*?)\s*\}\}", re.S)
# upstream refs may be template-namespaced ("${t0/search}") by the
# multi-template consolidator (DESIGN.md §8.1), hence the "/"
_REF = re.compile(r"\$\{([A-Za-z_][A-Za-z0-9_/]*)\}")


def _decouple(node: dict) -> Tuple[dict, List[dict], List[Tuple[str, str]]]:
    """Extract embedded tool directives from one LLM node dict."""
    prompt = node.get("prompt", "")
    tools: List[dict] = []
    edges: List[Tuple[str, str]] = []
    idx = 0

    def _sub(m: re.Match) -> str:
        nonlocal idx
        tool_id = f"{node['id']}__{m.group(1)}{idx}"
        idx += 1
        tools.append({
            "id": tool_id, "type": "tool", "op": m.group(1),
            "args": m.group(2),
        })
        edges.append((tool_id, node["id"]))
        return "${" + tool_id + "}"

    new_prompt = _DIRECTIVE.sub(_sub, prompt)
    out = dict(node)
    out["prompt"] = new_prompt
    return out, tools, edges


def parse_workflow(spec: dict) -> GraphSpec:
    """Parse a declarative workflow dict into a validated GraphSpec."""
    name = spec.get("name", "workflow")
    raw_nodes: List[dict] = []
    edges: List[Tuple[str, str]] = [tuple(e) for e in spec.get("edges", [])]

    for nd in spec["nodes"]:
        if nd.get("type", "llm") == "llm":
            nd2, tools, tedges = _decouple(nd)
            raw_nodes.append(nd2)
            raw_nodes.extend(tools)
            edges.extend(tedges)
        else:
            raw_nodes.append(dict(nd))
        # explicit deps list
        for dep in nd.get("deps", []):
            edges.append((dep, nd["id"]))

    # implicit edges from ${node} references in prompts / args
    ids = {nd["id"] for nd in raw_nodes}
    for nd in raw_nodes:
        for text in (nd.get("prompt", ""), nd.get("args", "")):
            for ref in _REF.findall(text):
                if ref in ids and ref != nd["id"]:
                    edges.append((ref, nd["id"]))

    nodes = []
    for nd in raw_nodes:
        ntype = NodeType(nd.get("type", "llm"))
        nodes.append(NodeSpec(
            id=nd["id"], type=ntype,
            model=nd.get("model", ""),
            prompt=nd.get("prompt", ""),
            max_new_tokens=int(nd.get("max_new_tokens", 32)),
            temperature=float(nd.get("temperature", 0.0)),
            op=nd.get("op", ""),
            args=nd.get("args", ""),
            est_prompt_tokens=int(nd.get("est_prompt_tokens", 64)),
            est_seconds=float(nd.get("est_seconds", 0.0)),
        ))
    # dedupe edges, keep deterministic order
    seen = set()
    uniq_edges = []
    for e in edges:
        if e not in seen:
            seen.add(e)
            uniq_edges.append(e)
    return GraphSpec(name, nodes, uniq_edges)


def render(template: str, binding: Dict[str, str],
           upstream: Dict[str, str]) -> str:
    """Instantiate a prompt/args template with binding params ($param)
    and upstream results (${node_id})."""
    def _ref_sub(m: re.Match) -> str:
        return upstream.get(m.group(1), m.group(0))

    out = _REF.sub(_ref_sub, template)
    # longest-first so $market_id wins over $market
    for key in sorted(binding, key=len, reverse=True):
        out = out.replace("$" + key, str(binding[key]))
    return out


def static_signature(template: str, binding: Dict[str, str]) -> str:
    """Template rendered with bindings only (upstream refs left symbolic) —
    used for STATIC coalescing before execution."""
    return render(template, binding, {})
