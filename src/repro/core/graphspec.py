"""GraphSpec — the typed workflow-DAG IR (paper §3, Parser output).

Nodes are either LLM invocations (GPU-resident) or tool calls
(CPU-resident: SQL / HTTP / local functions).  Edges carry data or
control dependencies.  The optimizer plans over the LLM-only projection
``llm_dag()`` (paper §4); tool nodes enter the cost model through
``T_prep``.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, replace
from typing import Dict, FrozenSet, Iterable, List, Sequence, Tuple


class NodeType(str, enum.Enum):
    """Whether a node runs on a GPU worker (LLM) or the CPU pool (TOOL)."""

    LLM = "llm"
    TOOL = "tool"


@dataclass(frozen=True)
class NodeSpec:
    """One workflow node: an LLM invocation or a tool call template."""

    id: str
    type: NodeType
    # --- LLM nodes -----------------------------------------------------
    model: str = ""                    # model id, e.g. "qwen3-14b"
    prompt: str = ""                   # template; $param / ${upstream_id}
    max_new_tokens: int = 32           # unit: tokens
    temperature: float = 0.0
    # --- tool nodes ------------------------------------------------------
    op: str = ""                       # "sql" | "http" | "pyfn"
    args: str = ""                     # template; $param / ${upstream_id}
    # ---------------------------------------------------------------------
    # static estimate hints (overridden by the online profiler)
    est_prompt_tokens: int = 64        # unit: tokens
    est_seconds: float = 0.0           # unit: s

    def is_llm(self) -> bool:
        """True for GPU-resident LLM nodes, False for CPU tool nodes."""
        return self.type == NodeType.LLM

    def with_(self, **kw) -> "NodeSpec":
        """A copy of this spec with the given fields replaced."""
        return replace(self, **kw)


class GraphSpec:
    """Validated DAG of NodeSpecs."""

    def __init__(self, name: str, nodes: Sequence[NodeSpec],
                 edges: Iterable[Tuple[str, str]]):
        self.name = name
        self.nodes: Dict[str, NodeSpec] = {}
        for n in nodes:
            if n.id in self.nodes:
                raise ValueError(f"duplicate node id {n.id!r}")
            self.nodes[n.id] = n
        self.edges: List[Tuple[str, str]] = []
        self._parents: Dict[str, List[str]] = {i: [] for i in self.nodes}
        self._children: Dict[str, List[str]] = {i: [] for i in self.nodes}
        for u, v in edges:
            if u not in self.nodes or v not in self.nodes:
                raise ValueError(f"edge ({u!r},{v!r}) references unknown node")
            if (u, v) in self.edges:
                continue
            self.edges.append((u, v))
            self._parents[v].append(u)
            self._children[u].append(v)
        self._topo = self._toposort()          # raises on cycles

    # ------------------------------------------------------------------
    def _toposort(self) -> List[str]:
        indeg = {i: len(self._parents[i]) for i in self.nodes}
        stack = sorted([i for i, d in indeg.items() if d == 0])
        out: List[str] = []
        while stack:
            v = stack.pop(0)
            out.append(v)
            for c in self._children[v]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    stack.append(c)
            stack.sort()                        # deterministic order
        if len(out) != len(self.nodes):
            raise ValueError(f"workflow {self.name!r} has a cycle")
        return out

    # ------------------------------------------------------------------
    def parents(self, v: str) -> List[str]:
        """Direct predecessors of ``v``."""
        return list(self._parents[v])

    def children(self, v: str) -> List[str]:
        """Direct successors of ``v``."""
        return list(self._children[v])

    def topo_order(self) -> List[str]:
        """All node ids in a deterministic topological order."""
        return list(self._topo)

    def llm_nodes(self) -> List[str]:
        """LLM node ids in topological order."""
        return [i for i in self._topo if self.nodes[i].is_llm()]

    def tool_nodes(self) -> List[str]:
        """Tool node ids in topological order."""
        return [i for i in self._topo if not self.nodes[i].is_llm()]

    def ancestors(self, v: str) -> FrozenSet[str]:
        """Every transitive predecessor of ``v``."""
        seen: set = set()
        stack = list(self._parents[v])
        while stack:
            u = stack.pop()
            if u not in seen:
                seen.add(u)
                stack.extend(self._parents[u])
        return frozenset(seen)

    # ------------------------------------------------------------------
    def llm_dag(self) -> "LLMDag":
        """Projection onto LLM nodes: edge u→v iff a path u⇝v exists using
        only tool nodes in between (the G_LLM of paper §4)."""
        llm = set(self.llm_nodes())
        edges: set = set()
        for src in llm:
            # BFS through tool nodes
            stack = list(self._children[src])
            seen: set = set()
            while stack:
                x = stack.pop()
                if x in seen:
                    continue
                seen.add(x)
                if x in llm:
                    edges.add((src, x))
                else:
                    stack.extend(self._children[x])
        return LLMDag(self, sorted(llm), sorted(edges))

    def tool_ancestors_between(self, v: str) -> List[str]:
        """Tool nodes on paths into LLM node v that do not cross another
        LLM node (the preparation set charged to T_prep(v))."""
        out: List[str] = []
        seen: set = set()
        stack = list(self._parents[v])
        while stack:
            u = stack.pop()
            if u in seen:
                continue
            seen.add(u)
            if not self.nodes[u].is_llm():
                out.append(u)
                stack.extend(self._parents[u])
        return sorted(out, key=self._topo.index)


class LLMDag:
    """The optimizer's view: LLM nodes only, with precedence edges."""

    def __init__(self, graph: GraphSpec, nodes: List[str],
                 edges: List[Tuple[str, str]]):
        self.graph = graph
        self.node_ids = list(nodes)
        self.edges = list(edges)
        self._parents: Dict[str, List[str]] = {i: [] for i in nodes}
        self._children: Dict[str, List[str]] = {i: [] for i in nodes}
        for u, v in edges:
            self._parents[v].append(u)
            self._children[u].append(v)

    def spec(self, v: str) -> NodeSpec:
        """The underlying NodeSpec of LLM node ``v``."""
        return self.graph.nodes[v]

    def parents(self, v: str) -> List[str]:
        """LLM-DAG predecessors of ``v``."""
        return list(self._parents[v])

    def children(self, v: str) -> List[str]:
        """LLM-DAG successors of ``v``."""
        return list(self._children[v])

    def frontier(self, done: FrozenSet[str]) -> List[str]:
        """Topological ready set: LLM preds all completed."""
        return [v for v in self.node_ids
                if v not in done and all(p in done for p in self._parents[v])]

    def is_valid_cut(self, done: FrozenSet[str], batch: FrozenSet[str]) -> bool:
        """Every LLM pred of each batch node is in done or in the batch."""
        return all(all(p in done or p in batch for p in self._parents[v])
                   for v in batch)

    def components(self, batch: FrozenSet[str]) -> List[List[str]]:
        """Weakly-connected components of the batch subgraph, each in topo
        order — the chains executed sequentially on one worker."""
        topo = [v for v in self.graph.topo_order() if v in batch]
        parent: Dict[str, str] = {v: v for v in batch}

        def _find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, v in self.edges:
            if u in batch and v in batch:
                parent[_find(u)] = _find(v)
        groups: Dict[str, List[str]] = {}
        for v in topo:
            groups.setdefault(_find(v), []).append(v)
        return list(groups.values())
