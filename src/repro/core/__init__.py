"""Halo core — the paper's contribution (parser → optimizer → processor).

Public API:

    from repro.core import (
        parse_workflow, consolidate, CostModel, EpochDPSolver,
        SolverConfig, SystemState, ExecutionPlan,
    )

    graph = parse_workflow(workflow_dict)          # §3 Parser
    batch = consolidate(graph, bindings)           # cross-query consolidation
    mega  = consolidate_multi([(graph_a, binds_a),  # cross-TEMPLATE mega-DAG
                               (graph_b, binds_b)])  # (DESIGN.md §8.1)
    cm    = CostModel(graph, HARDWARE["h200"], models, ...)
    plan  = EpochDPSolver(graph.llm_dag(), cm,
                          SolverConfig(num_workers=3)).solve()   # §4
    # runtime execution: repro.runtime.Processor                  # §5
"""
from repro.core.coalesce import CoalesceTable, canonical_signature
from repro.core.consolidate import (
    ConsolidatedGraph, MultiConsolidatedGraph, consolidate, consolidate_multi,
)
from repro.core.cost_model import (
    A100, H100, H200, HARDWARE, PAPER_MODELS, TPU_V5E, CostModel,
    EpochWeights, HardwareCalibration, HardwareProfile, LLMProfile,
    OperatorProfiler, profile_from_config,
)
from repro.core.graphspec import GraphSpec, LLMDag, NodeSpec, NodeType
from repro.core.optimality import optimality_score
from repro.core.oracle import BranchAndBoundOracle
from repro.core.parser import parse_workflow, render, static_signature
from repro.core.plan import Epoch, ExecutionPlan
from repro.core.schedulers import (
    SCHEDULERS, heft_plan, opwise_plan, random_plan, round_robin_plan,
)
from repro.core.solver import EpochDPSolver, SolverConfig
from repro.core.state import SystemState, WorkerContext

__all__ = [
    "CoalesceTable", "canonical_signature", "ConsolidatedGraph",
    "MultiConsolidatedGraph", "consolidate", "consolidate_multi",
    "CostModel", "EpochWeights", "HardwareCalibration",
    "HardwareProfile",
    "LLMProfile", "OperatorProfiler", "profile_from_config", "HARDWARE",
    "PAPER_MODELS", "H200", "H100", "A100", "TPU_V5E", "GraphSpec",
    "LLMDag", "NodeSpec", "NodeType", "optimality_score",
    "BranchAndBoundOracle", "parse_workflow", "render", "static_signature",
    "Epoch", "ExecutionPlan", "SCHEDULERS", "heft_plan", "opwise_plan",
    "random_plan", "round_robin_plan", "EpochDPSolver", "SolverConfig",
    "SystemState", "WorkerContext",
]
