"""ExecutionPlan — the Optimizer's output, consumed by the Processor.

An epoch launches a set of components (chains of LLM macro-nodes), one
component per GPU worker.  The plan also exposes the per-worker node
sequences (for the Opt(S) metric) and validates precedence.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.core.graphspec import LLMDag


@dataclass
class Epoch:
    """One plan step: chains of macro-nodes, one chain per worker."""

    # parallel lists: components[i] runs (in order) on workers[i]
    components: List[List[str]]
    workers: List[int]
    predicted_cost: float = 0.0

    def assignments(self) -> List[Tuple[str, int]]:
        """(node, worker) pairs of this epoch, in chain order."""
        out = []
        for comp, w in zip(self.components, self.workers):
            out.extend((v, w) for v in comp)
        return out


@dataclass
class ExecutionPlan:
    """The Optimizer's output: an ordered list of epochs."""

    epochs: List[Epoch] = field(default_factory=list)
    predicted_cost: float = 0.0
    solver_seconds: float = 0.0
    scheduler_name: str = ""

    # ------------------------------------------------------------------
    def node_order(self) -> List[Tuple[str, int]]:
        """(node, worker) pairs across every epoch, in plan order."""
        out = []
        for e in self.epochs:
            out.extend(e.assignments())
        return out

    def worker_sequences(self, num_workers: int) -> List[List[str]]:
        """Per-worker node sequences (the Processor's claim lists)."""
        seqs: List[List[str]] = [[] for _ in range(num_workers)]
        for e in self.epochs:
            for comp, w in zip(e.components, e.workers):
                seqs[w].extend(comp)
        return seqs

    def assignment_map(self) -> Dict[str, int]:
        """node id -> planned worker."""
        return {v: w for v, w in self.node_order()}

    # ------------------------------------------------------------------
    def validate(self, dag: LLMDag, done=()) -> None:
        """Check precedence/coverage; ``done`` seeds the completed set
        for tail plans solved from a non-empty SystemState."""
        done = set(done)
        for e in self.epochs:
            batch = {v for comp in e.components for v in comp}
            if len(e.components) != len(e.workers):
                raise ValueError("components/workers length mismatch")
            if len(set(e.workers)) != len(e.workers):
                raise ValueError("a worker got two components in one epoch")
            if not dag.is_valid_cut(frozenset(done), frozenset(batch)):
                raise ValueError("epoch violates precedence")
            # intra-epoch deps must be satisfied by component order
            for comp in e.components:
                seen_comp: set = set()
                for v in comp:
                    for p in dag.parents(v):
                        if p in batch and p not in seen_comp and p not in done:
                            if p not in comp:
                                raise ValueError(
                                    f"dep {p}->{v} crosses components in epoch")
                            raise ValueError(
                                f"dep {p}->{v} out of order inside component")
                    seen_comp.add(v)
            done |= batch
        missing = set(dag.node_ids) - done
        if missing:
            raise ValueError(f"plan misses nodes: {sorted(missing)}")
