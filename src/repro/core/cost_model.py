"""State-aware cost model  T(w,v,S) = T_prep + T_model + T_infer
(paper §4.1; DESIGN.md §8.2).

All GPU terms are ROOFLINE-DERIVED from hardware profiles rather than
magic constants:

* prefill is compute-bound:   t = 2 · P_active · tokens / (FLOPs · MFU)
* decode is bandwidth-bound:  t/step = (param_bytes + Σ KV bytes) / HBM_bw
  — which is precisely why continuous batching pays: the param-read term
  amortizes over the batch.
* model switch is host→HBM-bound: t = param_bytes / host_bw (+ eviction).
* the prefix-caching discount subtracts the matched warm-prefix tokens
  from effective prefill (whole-prefix only for recurrent-state archs,
  ``supports_partial_prefix=False``).

Tool terms come from the OperatorProfiler: an EXPLAIN-style estimate for
SQL (callable hook into the minidb), a signature-keyed moving average
for HTTP / local functions, continuously calibrated online.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Sequence, Tuple

from repro.core.graphspec import GraphSpec, NodeSpec
from repro.core.state import SystemState, WorkerContext


# ---------------------------------------------------------------------------
# hardware profiles
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HardwareProfile:
    """Roofline description of one GPU/TPU worker class."""

    name: str
    flops: float                 # unit: flops/s (peak bf16 per worker)
    hbm_bw: float                # unit: bytes/s @hbm
    hbm_bytes: float             # unit: bytes
    host_bw: float               # unit: bytes/s @host (weight-loading path)
    mfu: float = 0.45            # unit: 1 (achieved fraction of peak, prefill)
    bw_eff: float = 0.75         # unit: 1 (achieved fraction of HBM bw, decode)
    dispatch_overhead: float = 0.030   # unit: s (per-epoch coordination)
    link_bw: float = 450e9       # unit: bytes/s @link (worker↔worker KV link)


H200 = HardwareProfile("h200", 989e12, 4.8e12, 141e9, 55e9, link_bw=900e9)
H100 = HardwareProfile("h100", 989e12, 3.35e12, 80e9, 55e9, link_bw=900e9)
A100 = HardwareProfile("a100", 312e12, 2.0e12, 80e9, 25e9, link_bw=600e9)
TPU_V5E = HardwareProfile("tpu_v5e", 197e12, 819e9, 16e9, 32e9,
                          link_bw=186e9)

HARDWARE = {h.name: h for h in (H200, H100, A100, TPU_V5E)}


# ---------------------------------------------------------------------------
# model profiles (the LLMs *served inside workflows*; paper: Qwen3-14B/32B,
# GPT-OSS-20B + light 0.4B–4B variants)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LLMProfile:
    """Served-model size/bandwidth profile the roofline terms price."""

    name: str
    param_bytes: float           # unit: bytes @weights (resident, bf16)
    active_param_count: float    # unit: flops/token (2 FLOPs per param-token)
    # dimensionally params ARE flops/token up to the roofline's pure-number
    # 2.0 scalar, which is why t_prefill's algebra closes without a cast
    kv_bytes_per_token: float    # unit: bytes/token @kv (2*L*Hkv*Dh*2)
    supports_partial_prefix: bool = True

    @staticmethod
    def from_params(name: str, n_params: float, n_layers: int,
                    kv_heads: int, head_dim: int,
                    active_params: Optional[float] = None,
                    supports_partial_prefix: bool = True) -> "LLMProfile":
        """Build a profile from parameter count + KV geometry (bf16)."""
        return LLMProfile(
            name=name,
            param_bytes=2.0 * n_params,
            active_param_count=active_params or n_params,
            kv_bytes_per_token=2.0 * n_layers * kv_heads * head_dim * 2,
            supports_partial_prefix=supports_partial_prefix)


# paper's serving models (sizes from the respective tech reports)
PAPER_MODELS = {
    "qwen3-14b": LLMProfile.from_params("qwen3-14b", 14.8e9, 40, 8, 128),
    "qwen3-32b": LLMProfile.from_params("qwen3-32b", 32.8e9, 64, 8, 128),
    "gpt-oss-20b": LLMProfile.from_params(         # MoE: 3.6B active
        "gpt-oss-20b", 20.9e9, 24, 8, 64, active_params=3.6e9),
    "qwen3-0.6b": LLMProfile.from_params("qwen3-0.6b", 0.6e9, 28, 8, 128),
    "qwen3-4b": LLMProfile.from_params("qwen3-4b", 4.0e9, 36, 8, 128),
    "qwq-32b": LLMProfile.from_params("qwq-32b", 32.8e9, 64, 8, 128),
    "deepseek-r1-distill-32b": LLMProfile.from_params(
        "deepseek-r1-distill-32b", 32.8e9, 64, 8, 128),
}


def profile_from_config(cfg) -> LLMProfile:
    """Build an LLMProfile from a repro ModelConfig (assigned archs)."""
    return LLMProfile(
        name=cfg.name,
        param_bytes=2.0 * cfg.param_count(),
        active_param_count=float(cfg.active_param_count()),
        kv_bytes_per_token=2.0 * cfg.num_layers * cfg.num_kv_heads
        * cfg.resolved_head_dim * 2,
        supports_partial_prefix=cfg.supports_partial_prefix)


# ---------------------------------------------------------------------------
# operator profiler (tools + calibration)
# ---------------------------------------------------------------------------

class OperatorProfiler:
    """Signature-keyed latency estimates with online calibration (EWMA)."""

    def __init__(self, explain_hook: Optional[Callable[[str], float]] = None,
                 alpha: float = 0.3):
        self.explain_hook = explain_hook    # sql text -> est seconds
        self.alpha = alpha
        self._ewma: Dict[str, float] = {}
        self._count: Dict[str, int] = {}

    # unit: -> s
    def estimate(self, node: NodeSpec, rendered_args: str = "") -> float:
        """Expected seconds for one physical execution of ``node``."""
        key = f"{node.op}|{node.id}"
        if key in self._ewma:
            return self._ewma[key]
        if node.op == "sql" and self.explain_hook is not None:
            try:
                return self.explain_hook(rendered_args or node.args)
            except Exception:
                pass
        if node.est_seconds:
            return node.est_seconds
        return {"sql": 0.20, "http": 0.50, "pyfn": 0.05}.get(node.op, 0.10)

    def update(self, node_id: str, op: str, observed: float) -> None:
        """Fold one measured latency into the node's EWMA."""
        key = f"{op}|{node_id}"
        prev = self._ewma.get(key)
        self._ewma[key] = observed if prev is None else (
            self.alpha * observed + (1 - self.alpha) * prev)
        self._count[key] = self._count.get(key, 0) + 1

    @property
    def observations(self) -> int:
        """Total measured samples folded in so far."""
        return sum(self._count.values())

    def calibrated_keys(self) -> int:
        """How many distinct (op, node) keys have an online estimate."""
        return len(self._ewma)


class HardwareCalibration:
    """Fit the roofline's effective ``mfu``/``bw_eff`` knobs online.

    Only TOTAL node latency is observable, so this is a single
    time-scale fit: the ratio of predicted to observed latency rescales
    both knobs together (apportioning the total by predicted phase
    shares collapses to the same scalar, so the knobs stay correlated —
    decoupling them needs separately measured prefill/decode timings,
    a ROADMAP item).  An EWMA tracks the scale; ``profile()`` returns
    the base HardwareProfile with the calibrated knobs substituted —
    feed it back into a CostModel and predictions converge onto the
    machine actually running the batch.
    """

    def __init__(self, base: HardwareProfile, alpha: float = 0.5,
                 lo: float = 1e-4, hi: float = 10.0):
        self.base = base
        self.alpha = alpha
        self.lo, self.hi = lo, hi
        self.mfu = base.mfu
        self.bw_eff = base.bw_eff
        self.samples = 0

    def observe(self, t_prefill_pred: float, t_decode_pred: float,
                observed_s: float) -> None:
        """One (predicted prefill s, predicted decode s, measured s) sample.

        The predictions must come from a cost model currently using
        ``self.profile()`` (or ``base`` for the first sample) so the
        implied correction composes with prior calibration.
        """
        t_pred = t_prefill_pred + t_decode_pred
        if t_pred <= 0.0 or observed_s <= 0.0:
            return
        # single observable (total latency) -> single implied time-scale
        r = t_pred / observed_s            # <1: machine slower than modeled
        a = self.alpha
        self.mfu = (1 - a) * self.mfu + a * self._clip(self.mfu * r)
        self.bw_eff = (1 - a) * self.bw_eff + a * self._clip(self.bw_eff * r)
        self.samples += 1

    def _clip(self, x: float) -> float:
        return min(max(x, self.lo), self.hi)

    def profile(self) -> HardwareProfile:
        """The base profile with the calibrated knobs substituted."""
        return replace(self.base, mfu=self.mfu, bw_eff=self.bw_eff)

    def deltas(self) -> Dict[str, float]:
        """Calibrated-vs-static knob drift (for RunReport surfacing)."""
        return {
            "mfu_base": self.base.mfu, "mfu_eff": self.mfu,
            "bw_eff_base": self.base.bw_eff, "bw_eff_eff": self.bw_eff,
            "samples": self.samples,
        }


# ---------------------------------------------------------------------------
# the cost model
# ---------------------------------------------------------------------------

@dataclass
class EpochWeights:
    """The epoch-blend weights (makespan-vs-load mix, overhead weight)."""

    mu: float = 0.7              # unit: 1 (makespan vs aggregate-load blend)
    lam: float = 1.0             # unit: 1 (per-epoch overhead weight)


class CostModel:
    """State-aware latency model T(w, v, S) shared by planner+runtime."""

    def __init__(self, graph: GraphSpec, hardware: HardwareProfile,
                 models: Dict[str, LLMProfile],
                 profiler: Optional[OperatorProfiler] = None,
                 weights: Optional[EpochWeights] = None,
                 batch_sizes: Optional[Dict[str, int]] = None,
                 avg_context_tokens: float = 256.0,
                 use_profiling: bool = True,
                 use_prep_guidance: bool = True,
                 cpu_parallelism: int = 16,
                 use_migration: bool = True,
                 warm_aliases: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.graph = graph
        self.hw = hardware
        self.models = models
        self.profiler = profiler or OperatorProfiler()
        # fresh instance per model: a module-level default would be shared
        # (and mutable) across every CostModel in the process
        self.weights = weights if weights is not None else EpochWeights()
        # physical batch size per LLM node (after coalescing); default 1
        self.batch_sizes = dict(batch_sizes or {})
        self.avg_context_tokens = avg_context_tokens  # unit: tokens
        self.use_profiling = use_profiling   # ablation: naive dep-count scoring
        self.use_prep_guidance = use_prep_guidance  # ablation: no T_prep term
        self.cpu_parallelism = cpu_parallelism
        # credit cross-worker KV migration (peer warm lineage) when the
        # executor actually migrates; False for non-migrating systems so
        # plans aren't priced with savings execution can't realize
        self.use_migration = use_migration
        # cross-template warm-KV equivalences (multi-template mega-DAGs,
        # DESIGN.md §8.1): node v's warm lineage also satisfies any alias
        # of v — two templates with the identical static prompt share one
        # radix lineage at the engine, so the planner credits either id
        self.warm_aliases = dict(warm_aliases or {})

    # ------------------------------------------------------------- T_model
    # unit: -> s
    def t_model(self, v: NodeSpec, ctx: WorkerContext) -> float:
        """Model-switch cost: load ``v``'s weights unless resident."""
        if ctx.model == v.model:
            return 0.0
        prof = self.models[v.model]
        load = prof.param_bytes / self.hw.host_bw
        evict = 0.1 * load if ctx.model else 0.0      # memory mgmt to admit
        return load + evict

    # ------------------------------------------------------------- T_infer
    def _batch(self, v: NodeSpec) -> int:
        return max(self.batch_sizes.get(v.id, 1), 1)

    def _alias_closure(self, parents: Sequence[str]) -> Sequence[str]:
        """Parents plus their cross-template warm-KV aliases — any of
        them being warm in a context makes that context a valid donor."""
        if not self.warm_aliases:
            return parents
        out = list(parents)
        for p in parents:
            out.extend(self.warm_aliases.get(p, ()))
        return out

    # unit: -> tokens
    def _warm_shared_tokens(self, v: NodeSpec, ctx: WorkerContext,
                            parents: Sequence[str]) -> float:
        """Prompt tokens a warm parent lineage in ``ctx`` would cover."""
        p = float(v.est_prompt_tokens)
        # donors: the node's parents, their aliases, and the node's OWN
        # aliases (an alias that already ran left this node's identical
        # static prompt warm in the radix tree)
        donors = list(self._alias_closure(parents))
        donors += list(self.warm_aliases.get(v.id, ()))
        if ctx.warm_parent(donors) is None:
            return 0.0
        prof = self.models[v.model]
        if not prof.supports_partial_prefix:
            # recurrent state: only whole-prefix snapshots reusable; credit
            # the snapshot only when the warm parent context covers the
            # whole prompt (prompt == parent context + nothing new)
            return p if self.avg_context_tokens >= p else 0.0
        return min(self.avg_context_tokens, 0.75 * p)

    # unit: tokens=tokens -> s
    def t_migrate(self, v: NodeSpec, tokens: float) -> float:
        """Modeled cost of shipping ``tokens`` worth of one sequence's KV
        over the worker↔worker link (paper §5: Processor "KV-cache …
        migration").  One transfer serves the whole macro-batch — the
        imported donor is page-aliased by every request — so this does
        NOT scale with batch size."""
        prof = self.models[v.model]
        return tokens * prof.kv_bytes_per_token / self.hw.link_bw

    # unit: -> tokens s
    def prefill_plan(self, v: NodeSpec, ctx: WorkerContext,
                     parents: Sequence[str],
                     peer_ctxs: Sequence[WorkerContext] = ()
                     ) -> Tuple[float, float]:
        """(effective prefill tokens, t_migrate) for ``v`` on a worker
        with context ``ctx`` while the OTHER workers hold ``peer_ctxs``.

        Local warm lineage is free (page aliasing).  Otherwise, a peer
        worker holding the warm parent lineage can migrate its prefix
        pages over the link: the credit is granted only when the source
        context is actually warm AND the modeled transfer beats
        re-prefilling the same tokens — the migrate-vs-recompute
        decision the runtime KVMigrator mirrors.  Recurrent-state archs
        (supports_partial_prefix=False) never migrate: their state rows
        are not paged KV.
        """
        p = float(v.est_prompt_tokens)
        local = self._warm_shared_tokens(v, ctx, parents)
        if local > 0.0:
            return p - local, 0.0
        prof = self.models[v.model]
        if not self.use_migration or not prof.supports_partial_prefix:
            return p, 0.0
        remote = max((self._warm_shared_tokens(v, c, parents)
                      for c in peer_ctxs), default=0.0)
        if remote > 0.0:
            t_mig = self.t_migrate(v, remote)
            t_saved = self._roofline_times(v, remote, self._batch(v))[0]
            if t_mig < t_saved:
                return p - remote, t_mig
        return p, 0.0

    # unit: -> tokens
    def effective_prefill_tokens(self, v: NodeSpec, ctx: WorkerContext,
                                 parents: Sequence[str],
                                 peer_ctxs: Sequence[WorkerContext] = ()
                                 ) -> float:
        """Prompt tokens left to prefill after every warm-KV discount."""
        return self.prefill_plan(v, ctx, parents, peer_ctxs)[0]

    # unit: tokens=tokens -> 1
    def migration_wins(self, v: NodeSpec, tokens: float,
                       batch: Optional[int] = None) -> bool:
        """True when migrating ``tokens`` of warm KV beats re-prefilling
        them — the runtime migrator's go/no-go check.  ``batch`` defaults
        to the node's planned batch size, the SAME n prefill_plan scales
        its savings by, so the runtime decision agrees with the credit
        the solver priced the placement with."""
        if tokens <= 0:
            return False
        prof = self.models[v.model]
        if not prof.supports_partial_prefix:
            return False
        n = batch if batch is not None else self._batch(v)
        t_saved = self._roofline_times(v, tokens, max(n, 1))[0]
        return self.t_migrate(v, tokens) < t_saved

    # unit: eff_p=tokens n=1 -> s s
    def _roofline_times(self, v: NodeSpec, eff_p: float, n: int
                        ) -> Tuple[float, float]:
        """(t_prefill, t_decode): the single source of the roofline
        formulas — both planning (t_infer) and online calibration
        (infer_breakdown) must price GPU work identically or the
        calibrated knobs decouple from the plans they steer."""
        prof = self.models[v.model]
        t_prefill = (2.0 * prof.active_param_count * eff_p * n
                     / (self.hw.flops * self.hw.mfu))
        # decode: each step reads the weights once + the batch's KV
        ctx_len = self.avg_context_tokens + v.est_prompt_tokens
        kv_read = n * prof.kv_bytes_per_token * ctx_len
        # the bytes above are read once per step, and a step emits one
        # token — so the quotient is a per-token time, not a total
        t_step = ((prof.param_bytes + kv_read)
                  / (self.hw.hbm_bw * self.hw.bw_eff))  # unit: s/token
        return t_prefill, v.max_new_tokens * t_step

    # unit: -> s s
    def infer_breakdown(self, v: NodeSpec,
                        batch: Optional[int] = None
                        ) -> Tuple[float, float]:
        """(t_prefill, t_decode) for a cold context — the two roofline
        phases the online HardwareCalibration fits its knobs from."""
        n = batch if batch is not None else self._batch(v)
        return self._roofline_times(v, float(v.est_prompt_tokens), n)

    # unit: -> s
    def t_infer(self, v: NodeSpec, ctx: WorkerContext,
                parents: Sequence[str],
                peer_ctxs: Sequence[WorkerContext] = ()) -> float:
        """Roofline prefill+decode (+migration) time for one macro-node."""
        n = self._batch(v)
        if not self.use_profiling:
            # ablation "w/o profiling scoring": score by dependency count
            return 0.05 * (1 + len(parents)) * n
        eff_p, t_mig = self.prefill_plan(v, ctx, parents, peer_ctxs)
        t_prefill, t_decode = self._roofline_times(v, eff_p, n)
        return t_prefill + t_decode + t_mig

    # -------------------------------------------------------------- T_prep
    # unit: -> s
    def t_prep(self, v: NodeSpec, done: frozenset) -> float:
        """Critical path of unmaterialized tool ancestors feeding v.

        Each pending tool macro-node runs its (coalesced) physical batch
        across the bounded CPU pool; chained tools add up (they are a
        sequential path into v).
        """
        if not self.use_prep_guidance:
            return 0.0
        tools = self.graph.tool_ancestors_between(v.id)
        pend = [t for t in tools if t not in done]
        t_total = 0.0
        for t_id in pend:
            spec = self.graph.nodes[t_id]
            n_phys = self.batch_sizes.get(t_id, 1)   # after coalescing
            waves = math.ceil(n_phys / self.cpu_parallelism)
            t_total += self.profiler.estimate(spec) * waves
        return t_total

    # ------------------------------------------------------------- T total
    # unit: -> s -
    def t_node(self, v_id: str, ctx: WorkerContext, done: frozenset,
               peer_ctxs: Sequence[WorkerContext] = ()
               ) -> Tuple[float, WorkerContext]:
        """Latency of one (macro-)node on a worker + the context after.

        ``peer_ctxs`` — the OTHER workers' contexts — lets the prefill
        term price a cross-worker KV migration when the parent lineage
        is warm elsewhere (see :meth:`prefill_plan`)."""
        v = self.graph.nodes[v_id]
        parents = self.graph.parents(v_id)
        t = (self.t_prep(v, done)
             + self.t_model(v, ctx)
             + self.t_infer(v, ctx, parents, peer_ctxs))
        return t, ctx.after(v_id, v.model)

    # ---------------------------------------------------------- epoch cost
    # unit: busy_values=s -> s
    def epoch_blend(self, busy_values: Sequence[float]) -> float:
        """The epoch scoring blend over per-worker busy times — shared by
        the solver's predictions AND the online drift monitor's observed
        costs: both must score identically or drift over/under-fires."""
        mu, lam = self.weights.mu, self.weights.lam
        return (mu * max(busy_values) + (1 - mu) * sum(busy_values)
                + lam * self.hw.dispatch_overhead)

    # unit: -> s - -
    def epoch_cost(self, components: Sequence[Sequence[str]],
                   workers: Sequence[int], state: SystemState
                   ) -> Tuple[float, Tuple[WorkerContext, ...], Dict[int, float]]:
        """Cost of launching ``components[i]`` on ``workers[i]``.

        Returns (C_epoch, next worker contexts, per-worker busy time).
        Chained nodes on one worker see the evolving context (model kept
        resident, parent lineage warm — the locality the DP rewards).
        """
        ctxs = list(state.contexts)
        t_w: Dict[int, float] = {}
        done = set(state.done)
        for comp, w in zip(components, workers):
            ctx = ctxs[w]
            # peers at EPOCH START: components run concurrently, so a
            # migration source is priced from the state the epoch opened
            # with, not from a sibling component's mid-epoch progress
            peers = tuple(c for x, c in enumerate(state.contexts) if x != w)
            busy = 0.0
            for v_id in comp:
                t, ctx = self.t_node(v_id, ctx, frozenset(done), peers)
                busy += t
                done.add(v_id)
            ctxs[w] = ctx
            t_w[w] = t_w.get(w, 0.0) + busy
        return self.epoch_blend(list(t_w.values())), tuple(ctxs), t_w
