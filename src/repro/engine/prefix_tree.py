"""Radix tree over token ids — shared-prefix detection for KV reuse.

Used by (i) the engine to find how much of a new prompt's KV is already
resident (prefix-caching discount), and (ii) Halo's consolidator to pick
the template prefix shared by a batch of workflow-bound prompts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class _Node:
    children: Dict[int, "_Node"] = field(default_factory=dict)
    # number of inserted sequences passing through this node
    count: int = 0
    # opaque payload attached at the deepest node of an inserted sequence
    # (the engine stores the paged-KV sequence id here)
    payload: Optional[object] = None
    # recent path-stamped payloads, newest first (bounded): fallback
    # donors for when the newest one's KV pages get evicted
    payloads: List[object] = field(default_factory=list)

    MAX_STAMPS = 4

    def stamp(self, payload: object) -> None:
        self.payloads = [p for p in self.payloads
                         if p is not payload and p != payload]
        self.payloads.insert(0, payload)
        del self.payloads[self.MAX_STAMPS:]
        self.payload = payload


class RadixPrefixTree:
    """Token-level radix tree (one token per edge — simple and exact)."""

    def __init__(self):
        self.root = _Node()
        self.num_sequences = 0

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], payload: object = None,
               stamp_path: bool = False) -> None:
        """Insert ``tokens``; attach ``payload`` at the deepest node.

        With ``stamp_path`` the payload is also stamped on every interior
        node of the path, making this sequence the *representative donor*
        for each of its prefixes — a later ``match()`` that diverges
        mid-sequence then still yields a payload covering the matched
        prefix (the engine uses this for partial-prompt KV-page reuse).
        """
        node = self.root
        node.count += 1
        for t in tokens:
            # not-a-sync: tokens is the host-side prompt tuple
            node = node.children.setdefault(int(t), _Node())
            node.count += 1
            if stamp_path:
                node.stamp(payload)
        node.payload = payload
        self.num_sequences += 1

    def match(self, tokens: Sequence[int]) -> Tuple[int, Optional[object]]:
        """Longest cached prefix of ``tokens``.

        Returns (match_len, payload of the deepest payload-bearing node on
        the matched path).
        """
        n, cands = self.match_all(tokens)
        return n, cands[0][1] if cands else None

    def match_all(self, tokens: Sequence[int]
                  ) -> Tuple[int, List[Tuple[int, object]]]:
        """Longest cached prefix plus every (depth, payload) pair on the
        matched path, deepest-first and payload-deduplicated.

        A payload stamped at depth d certifies only that its sequence
        shares the first d tokens, so each candidate carries its own
        depth.  Callers whose payloads can go stale (the engine's
        evicted KV sequences) walk the candidates instead of giving up
        when the most recent donor stamped over an older, still-valid
        one.
        """
        def node_payloads(node) -> List[object]:
            ps = list(node.payloads)
            if node.payload is not None and all(
                    q is not node.payload and q != node.payload for q in ps):
                ps.insert(0, node.payload)
            return ps

        node = self.root
        found: List[Tuple[int, List[object]]] = [(0, node_payloads(node))]
        n = 0
        for t in tokens:
            # not-a-sync: tokens is the host-side prompt tuple
            child = node.children.get(int(t))
            if child is None:
                break
            node = child
            n += 1
            found.append((n, node_payloads(node)))
        out: List[Tuple[int, object]] = []
        for depth, ps in reversed(found):              # deepest first
            for p in ps:                               # newest first
                if all(q is not p and q != p for _, q in out):
                    out.append((depth, p))
        return n, out

    # ------------------------------------------------------------------
    def longest_common_prefix(self) -> List[int]:
        """LCP over ALL inserted sequences (the batch's template prefix)."""
        out: List[int] = []
        node = self.root
        total = node.count
        while len(node.children) == 1:
            (tok, child), = node.children.items()
            if child.count != total:
                break
            out.append(tok)
            node = child
        return out


def common_prefix_length(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def batch_shared_prefix(prompts: Sequence[Sequence[int]]) -> List[int]:
    """Longest prefix shared by every prompt in the batch."""
    if not prompts:
        return []
    out = list(prompts[0])
    for p in prompts[1:]:
        n = common_prefix_length(out, p)
        del out[n:]
        if not out:
            break
    return out
