"""Radix tree over token ids — shared-prefix detection for KV reuse.

Used by (i) the engine to find how much of a new prompt's KV is already
resident (prefix-caching discount), and (ii) Halo's consolidator to pick
the template prefix shared by a batch of workflow-bound prompts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class _Node:
    children: Dict[int, "_Node"] = field(default_factory=dict)
    # number of inserted sequences passing through this node
    count: int = 0
    # opaque payload attached at the deepest node of an inserted sequence
    # (the engine stores (worker_id, kv_page_ids) here)
    payload: Optional[object] = None


class RadixPrefixTree:
    """Token-level radix tree (one token per edge — simple and exact)."""

    def __init__(self):
        self.root = _Node()
        self.num_sequences = 0

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], payload: object = None) -> None:
        node = self.root
        node.count += 1
        for t in tokens:
            node = node.children.setdefault(int(t), _Node())
            node.count += 1
        node.payload = payload
        self.num_sequences += 1

    def match(self, tokens: Sequence[int]) -> Tuple[int, Optional[object]]:
        """Longest cached prefix of ``tokens``.

        Returns (match_len, payload of the deepest payload-bearing node on
        the matched path).
        """
        node = self.root
        best_payload = node.payload
        n = 0
        for t in tokens:
            child = node.children.get(int(t))
            if child is None:
                break
            node = child
            n += 1
            if node.payload is not None:
                best_payload = node.payload
        return n, best_payload

    # ------------------------------------------------------------------
    def longest_common_prefix(self) -> List[int]:
        """LCP over ALL inserted sequences (the batch's template prefix)."""
        out: List[int] = []
        node = self.root
        total = node.count
        while len(node.children) == 1:
            (tok, child), = node.children.items()
            if child.count != total:
                break
            out.append(tok)
            node = child
        return out


def common_prefix_length(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def batch_shared_prefix(prompts: Sequence[Sequence[int]]) -> List[int]:
    """Longest prefix shared by every prompt in the batch."""
    if not prompts:
        return []
    out = list(prompts[0])
    for p in prompts[1:]:
        n = common_prefix_length(out, p)
        del out[n:]
        if not out:
            break
    return out
