"""Mixture-of-Experts FFN (GShard-style grouped dispatch, capacity-clamped).

TPU-native design notes (DESIGN.md §4):
* tokens are processed in ``num_groups`` groups so dispatch bookkeeping stays
  local to a data shard (the group dim is sharded over the ``data`` axis);
* dispatch uses cumsum-position + scatter-add into an ``(G, E, C, D)`` buffer
  (dense one-hot dispatch tensors of shape (N, E, C) would be O(10^13) at the
  assigned train_4k scale — infeasible);
* the expert dim is sharded over the ``model`` axis when divisible
  (deepseek-moe: 64/16), otherwise the per-expert FFN dim is sharded
  (mixtral: 8 experts × d_ff/16).  See distribution/sharding.py.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.engine.models.layers import dense_init

# memspace: device (model arrays are device-resident jnp values)


def moe_init(rng, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(rng, 5)
    E, F = cfg.num_experts, cfg.d_ff_expert
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": (jax.random.normal(ks[0], (d_model, E), jnp.float32) * scale
                   ).astype(jnp.float32),                       # router in f32
        "w_gate": (jax.random.normal(ks[1], (E, d_model, F), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, F), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d_model), jnp.float32)
                   * (1.0 / math.sqrt(F))).astype(dtype),
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * F
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kss[0], d_model, Fs, dtype),
            "w_up": dense_init(kss[1], d_model, Fs, dtype),
            "w_down": dense_init(kss[2], Fs, d_model, dtype),
        }
    return p


def _dispatch_indices(top_idx: jax.Array, num_experts: int, capacity: int):
    """top_idx: (N, K) expert ids  ->  (slot positions within expert, keep mask).

    Position of slot (n, k) inside its expert's capacity buffer = number of
    earlier slots routed to the same expert (row-major (n, k) order).
    """
    N, K = top_idx.shape
    flat = top_idx.reshape(-1)                                   # (N*K,)
    onehot = jax.nn.one_hot(flat, num_experts, dtype=jnp.int32)  # (N*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                         # (N*K, E)
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                    # (N*K,)
    keep = pos_in_e < capacity
    return pos_in_e.reshape(N, K), keep.reshape(N, K)


def _group_moe(x_g, p, cfg: MoEConfig, capacity: int):
    """x_g: (N, D) tokens of one group -> (N, D) output + load stats."""
    N, D = x_g.shape
    E, K = cfg.num_experts, cfg.top_k

    logits = (x_g.astype(jnp.float32) @ p["router"])             # (N, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                       # (N, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    pos, keep = _dispatch_indices(top_i, E, capacity)            # (N, K)

    # ---- scatter tokens into the (E, C, D) buffer ------------------------
    e_idx = jnp.where(keep, top_i, E - 1).reshape(-1)
    c_idx = jnp.where(keep, pos, capacity - 1).reshape(-1)
    src = jnp.repeat(x_g, K, axis=0) * keep.reshape(-1, 1).astype(x_g.dtype)
    buf = jnp.zeros((E, capacity, D), x_g.dtype)
    buf = buf.at[e_idx, c_idx].add(src)

    # ---- expert computation (SwiGLU) -------------------------------------
    h_gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])         # (E, C, D)

    # ---- combine ----------------------------------------------------------
    gathered = out_buf[e_idx, c_idx]                             # (N*K, D)
    # keep is bool: cast before the multiply (f32*bool has no promotion
    # path under jax_numpy_dtype_promotion=strict, the CI dtype leg)
    w = (top_w.reshape(-1, 1)
         * keep.reshape(-1, 1).astype(top_w.dtype)).astype(out_buf.dtype)
    out = (gathered * w).reshape(N, K, D).sum(axis=1)

    # ---- load-balancing stats (Switch aux loss terms) ---------------------
    frac_tokens = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = probs.mean(axis=0)
    return out, frac_tokens, mean_probs


def moe_ffn(x: jax.Array, p, cfg: MoEConfig, num_groups: int = 0
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    N = B * S
    G = num_groups or min(B, 16)
    while N % G:
        G -= 1
    Ng = N // G
    capacity = max(int(math.ceil(Ng * cfg.top_k / cfg.num_experts
                                 * cfg.capacity_factor)), cfg.top_k)

    xg = x.reshape(G, Ng, D)
    out, frac, meanp = jax.vmap(lambda t: _group_moe(t, p, cfg, capacity))(xg)
    aux = cfg.num_experts * jnp.mean(jnp.mean(frac, 0) * jnp.mean(meanp, 0))

    y = out.reshape(B, S, D)
    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return y, aux.astype(jnp.float32)
