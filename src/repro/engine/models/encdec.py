"""Encoder-decoder transformer (whisper-tiny backbone).

The conv/mel frontend is a STUB per assignment: the model consumes
precomputed frame embeddings ``frames`` (B, T_enc, d_model).  Encoder is
bidirectional; decoder is causal with cross-attention.  Whisper uses
LayerNorm (+bias) and non-gated GELU FFNs; positions are sinusoidal.

Decode shapes use the decoder self-attn cache + precomputed cross-attn KV
over the encoder output (frames length = min(enc_max_len, seq_len)).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.engine.models import layers as L

# memspace: device (model arrays are device-resident jnp values)

Params = Dict[str, Any]


def _ln_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _ffn_init(rng, d, d_ff, dtype):
    k1, k2 = jax.random.split(rng)
    return {"w_up": L.dense_init(k1, d, d_ff, dtype),
            "w_down": L.dense_init(k2, d_ff, d, dtype)}


class EncDecLM:
    """Whisper-style encoder-decoder with stubbed audio frontend."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.head_dim = cfg.resolved_head_dim
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------ init
    def _enc_block_init(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {
            "ln1": _ln_init(cfg.d_model, self.dtype),
            "ln2": _ln_init(cfg.d_model, self.dtype),
            "attn": L.attn_init(k1, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, self.head_dim, self.dtype),
            "ffn": _ffn_init(k2, cfg.d_model, cfg.d_ff, self.dtype),
        }

    def _dec_block_init(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "ln1": _ln_init(cfg.d_model, self.dtype),
            "ln_x": _ln_init(cfg.d_model, self.dtype),
            "ln2": _ln_init(cfg.d_model, self.dtype),
            "attn": L.attn_init(k1, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, self.head_dim, self.dtype),
            "xattn": L.attn_init(k2, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, self.head_dim, self.dtype),
            "ffn": _ffn_init(k3, cfg.d_model, cfg.d_ff, self.dtype),
        }

    def init(self, rng) -> Params:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        enc_ks = jax.random.split(ks[1], cfg.enc_layers)
        dec_ks = jax.random.split(ks[2], cfg.num_layers)
        return {
            "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, self.dtype),
            "enc_blocks": jax.vmap(self._enc_block_init)(enc_ks),
            "dec_blocks": jax.vmap(self._dec_block_init)(dec_ks),
            "enc_ln": _ln_init(cfg.d_model, self.dtype),
            "dec_ln": _ln_init(cfg.d_model, self.dtype),
        }

    # --------------------------------------------------------------- encoder
    def encode(self, params: Params, frames: jax.Array,
               impl: Optional[str] = None) -> jax.Array:
        """frames: (B, T, D) stub frontend output -> encoder states."""
        cfg = self.cfg
        B, T, D = frames.shape
        pe = L.sinusoidal_positions(T, D).astype(self.dtype)
        x = frames.astype(self.dtype) + pe[None]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def body(x, p):
            h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=1.0, use_rope=False)
            o = L.attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=False,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
            return x + L.ffn_apply_nogate(p["ffn"], h), None

        x, _ = lax.scan(body, x, params["enc_blocks"])
        return L.layer_norm(x, params["enc_ln"]["w"], params["enc_ln"]["b"])

    # --------------------------------------------------------------- decoder
    def _dec_stack(self, params, x, positions, enc_out, enc_positions,
                   impl=None, self_kv=None):
        """Shared decoder stack. If self_kv is given (decode path), it is a
        (k_cache, v_cache, kv_positions, slot) tuple per-layer handled by the
        scan body; otherwise full-sequence self attention."""
        cfg = self.cfg

        def body(x, p):
            h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=1.0, use_rope=False)
            o = L.attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            # cross attention over encoder states
            h = L.layer_norm(x, p["ln_x"]["w"], p["ln_x"]["b"])
            B, S, _ = h.shape
            qx = (h @ p["xattn"]["wq"]).reshape(B, S, cfg.num_heads, self.head_dim)
            kx = (enc_out @ p["xattn"]["wk"]).reshape(
                B, -1, cfg.num_kv_heads, self.head_dim)
            vx = (enc_out @ p["xattn"]["wv"]).reshape(
                B, -1, cfg.num_kv_heads, self.head_dim)
            ox = L.attention(qx, kx, vx, q_positions=positions,
                             kv_positions=enc_positions, causal=False,
                             impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["xattn"], ox)
            h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
            return x + L.ffn_apply_nogate(p["ffn"], h), None

        x, _ = lax.scan(body, x, params["dec_blocks"])
        return x

    def forward(self, params: Params, tokens: jax.Array, frames: jax.Array,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forced decode over the full sequence -> (logits, aux=0)."""
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        B, T_enc, _ = enc_out.shape
        enc_positions = jnp.broadcast_to(
            jnp.arange(T_enc, dtype=jnp.int32), (B, T_enc))
        S = tokens.shape[1]
        pe = L.sinusoidal_positions(S, cfg.d_model).astype(self.dtype)
        x = params["embed"][tokens] + pe[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._dec_stack(params, x, positions, enc_out, enc_positions)
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
        logits = x @ params["embed"].T          # tied head (whisper ties)
        return logits, jnp.float32(0.0)

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = False) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"], batch["frames"],
                                 remat=remat)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               self.cfg.vocab_size,
                               mask=batch.get("loss_mask"))

    # ------------------------------------------------------------- KV cache
    def cache_batch_axes(self, cache):
        return {k: (0 if k in ("length", "enc_len") else 1) for k in cache}

    def paged_kv_layout(self):
        """Cross-attention KV is frame-indexed, not token-paged — the
        continuous-batching engine keeps enc/dec state as dense rows."""
        return None

    def extend_cache(self, cache, extra: int):
        out = dict(cache)
        for key in ("k", "v"):
            c = cache[key]
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, extra)
            out[key] = jnp.pad(c, pad)
        # normalize cross-attn KV to enc_max_len so rows from requests
        # with different frame counts stack into one decode batch
        # (enc_len masks the padded slots, contributing exact zeros)
        t_enc = cache["xk"].shape[2]
        if t_enc < self.cfg.enc_max_len:
            for key in ("xk", "xv"):
                c = out[key]
                pad = [(0, 0)] * c.ndim
                pad[2] = (0, self.cfg.enc_max_len - t_enc)
                out[key] = jnp.pad(c, pad)
        return out

    def init_cache(self, batch: int, max_len: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        Ld, Hkv, Dh = cfg.num_layers, cfg.num_kv_heads, self.head_dim
        T_enc = cfg.enc_max_len
        return {
            "k": jnp.zeros((Ld, batch, max_len, Hkv, Dh), self.dtype),
            "v": jnp.zeros((Ld, batch, max_len, Hkv, Dh), self.dtype),
            # cross-attn KV precomputed at prefill
            "xk": jnp.zeros((Ld, batch, T_enc, Hkv, Dh), self.dtype),
            "xv": jnp.zeros((Ld, batch, T_enc, Hkv, Dh), self.dtype),
            "enc_len": jnp.zeros((batch,), jnp.int32),
            "length": jnp.zeros((batch,), jnp.int32),
        }

    def prefill(self, params: Params, tokens: jax.Array, frames: jax.Array,
                impl: Optional[str] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Encode frames, run the decoder prompt, build caches."""
        cfg = self.cfg
        enc_out = self.encode(params, frames, impl=impl)
        B, T_enc, _ = enc_out.shape
        enc_positions = jnp.broadcast_to(
            jnp.arange(T_enc, dtype=jnp.int32), (B, T_enc))
        S = tokens.shape[1]
        pe = L.sinusoidal_positions(S, cfg.d_model).astype(self.dtype)
        x = params["embed"][tokens] + pe[None]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(x, p):
            h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=1.0, use_rope=False)
            o = L.attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.layer_norm(x, p["ln_x"]["w"], p["ln_x"]["b"])
            qx = (h @ p["xattn"]["wq"]).reshape(B, S, cfg.num_heads, self.head_dim)
            kx = (enc_out @ p["xattn"]["wk"]).reshape(
                B, T_enc, cfg.num_kv_heads, self.head_dim)
            vx = (enc_out @ p["xattn"]["wv"]).reshape(
                B, T_enc, cfg.num_kv_heads, self.head_dim)
            ox = L.attention(qx, kx, vx, q_positions=positions,
                             kv_positions=enc_positions, causal=False,
                             impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["xattn"], ox)
            h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
            return x + L.ffn_apply_nogate(p["ffn"], h), (k, v, kx, vx)

        x, (ks, vs, xks, xvs) = lax.scan(body, x, params["dec_blocks"])
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
        logits = x[:, -1] @ params["embed"].T
        cache = {
            "k": ks, "v": vs, "xk": xks, "xv": xvs,
            "enc_len": jnp.full((B,), T_enc, jnp.int32),
            "length": jnp.full((B,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params: Params, token: jax.Array,
                    cache: Dict[str, jax.Array],
                    impl: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        B = token.shape[0]
        pos = cache["length"]
        T = cache["k"].shape[2]
        T_enc = cache["xk"].shape[2]
        pe = L.sinusoidal_positions(T, cfg.d_model).astype(self.dtype)
        x = params["embed"][token][:, None, :] + pe[pos][:, None, :]
        slots = jnp.arange(T, dtype=jnp.int32)[None, :]
        kv_pos = jnp.where((slots <= pos[:, None]), slots, -1)
        enc_positions = jnp.where(
            jnp.arange(T_enc, dtype=jnp.int32)[None, :] < cache["enc_len"][:, None],
            jnp.arange(T_enc, dtype=jnp.int32)[None, :], -1)
        batch_ix = jnp.arange(B, dtype=jnp.int32)

        def body(x, xs):
            p, k_c, v_c, xk, xv = xs
            h = L.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim,
                                 positions=pos[:, None],
                                 rope_theta=1.0, use_rope=False)
            k_c = k_c.at[batch_ix, pos].set(k[:, 0])
            v_c = v_c.at[batch_ix, pos].set(v[:, 0])
            o = L.attention(q, k_c, v_c, q_positions=pos[:, None],
                            kv_positions=kv_pos, causal=True,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.layer_norm(x, p["ln_x"]["w"], p["ln_x"]["b"])
            qx = (h @ p["xattn"]["wq"]).reshape(B, 1, cfg.num_heads, self.head_dim)
            ox = L.attention(qx, xk, xv, q_positions=pos[:, None],
                             kv_positions=enc_positions, causal=False,
                             impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["xattn"], ox)
            h = L.layer_norm(x, p["ln2"]["w"], p["ln2"]["b"])
            return x + L.ffn_apply_nogate(p["ffn"], h), (k_c, v_c)

        x, (ks, vs) = lax.scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"],
             cache["xk"], cache["xv"]))
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ks, vs
        new_cache["length"] = pos + 1
        x = L.layer_norm(x, params["dec_ln"]["w"], params["dec_ln"]["b"])
        return x[:, -1] @ params["embed"].T, new_cache
