"""Griffin-style hybrid LM (recurrentgemma-2b): RG-LRU + local attention.

Block pattern ``(rglru, rglru, attn)`` tiled over ``num_layers`` (26 = 8
full groups + 2 leftover recurrent blocks).  Every temporal-mix block is
followed by a GeGLU MLP; residuals around both.

* RG-LRU: r,i = sigmoid gates; log a = -c·softplus(Λ)·r (c=8);
  h_t = a_t·h_{t-1} + sqrt(1-a_t²)·(i_t·x_t) — an elementwise linear
  recurrence.  Sequence mode runs ``jax.lax.associative_scan`` (XLA ref)
  or the Pallas blocked-scan kernel; decode is the single-step form.
* Local attention: MQA (kv=1), rope, sliding window; the KV cache is a
  ring buffer of ``local_attn_window`` slots, which bounds memory for the
  long_500k decode shape.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.engine.models import layers as L
from repro.engine.models.xlstm import causal_conv1d, causal_conv1d_step

# memspace: device (model arrays are device-resident jnp values)

Params = Dict[str, Any]
RG_C = 8.0


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_gates(p, u: jax.Array):
    """u: (..., D_rnn) -> (a, b) of the recurrence h = a*h_prev + b."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(u32 @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -RG_C * jax.nn.softplus(p["lam"]) * r           # (..., D) f32
    a = jnp.exp(log_a)
    scale = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    b = scale * (i * u32)
    return a, b


def rglru_sequence(p, u: jax.Array, impl: str = "xla") -> jax.Array:
    """u: (B,S,D) -> h: (B,S,D) from zero initial state."""
    a, b = rglru_gates(p, u)
    if impl == "xla":
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        _, h = lax.associative_scan(combine, (a, b), axis=1)
        return h.astype(u.dtype)
    from repro.kernels.rglru_scan import ops as lru_ops
    return lru_ops.linear_scan(
        a, b, interpret=(impl == "pallas_interpret")).astype(u.dtype)


def rglru_step(p, u_t: jax.Array, h_prev: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """u_t: (B,D); h_prev: (B,D) f32."""
    a, b = rglru_gates(p, u_t)
    h = a * h_prev + b
    return h.astype(u_t.dtype), h


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.head_dim = cfg.resolved_head_dim
        self.d_rnn = cfg.lru_width or cfg.d_model
        self.pattern = cfg.block_pattern or ("rglru", "rglru", "attn")
        self.glen = len(self.pattern)
        self.n_groups = cfg.num_layers // self.glen
        self.n_leftover = cfg.num_layers % self.glen

    # ------------------------------------------------------------------ init
    def _mlp_init(self, rng):
        cfg = self.cfg
        k1 = jax.random.fold_in(rng, 1)
        return {"ln": jnp.zeros((cfg.d_model,), self.dtype),
                **L.ffn_init(k1, cfg.d_model, cfg.d_ff, self.dtype)}

    def _rblock_init(self, rng):
        cfg = self.cfg
        d, dr = cfg.d_model, self.d_rnn
        ks = jax.random.split(rng, 7)
        return {
            "ln": jnp.zeros((d,), self.dtype),
            "w_gate": L.dense_init(ks[0], d, dr, self.dtype),
            "w_in": L.dense_init(ks[1], d, dr, self.dtype),
            "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, dr),
                                         jnp.float32) * 0.1).astype(self.dtype),
            "rg": {
                "w_a": (jax.random.normal(ks[3], (dr, dr), jnp.float32)
                        / jnp.sqrt(dr)).astype(self.dtype),
                "b_a": jnp.zeros((dr,), jnp.float32),
                "w_x": (jax.random.normal(ks[4], (dr, dr), jnp.float32)
                        / jnp.sqrt(dr)).astype(self.dtype),
                "b_x": jnp.zeros((dr,), jnp.float32),
                # init Λ so decay a ∈ (0.9, 0.999) at r=0.5, as in the paper
                "lam": jnp.linspace(-2.0, 1.0, dr).astype(jnp.float32),
            },
            "w_out": L.dense_init(ks[5], dr, d, self.dtype),
            "mlp": self._mlp_init(ks[6]),
        }

    def _ablock_init(self, rng):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {
            "ln": jnp.zeros((cfg.d_model,), self.dtype),
            "attn": L.attn_init(k1, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, self.head_dim, self.dtype),
            "mlp": self._mlp_init(k2),
        }

    def _group_init(self, rng):
        ks = jax.random.split(rng, self.glen)
        out = {}
        for i, kind in enumerate(self.pattern):
            out[f"b{i}"] = (self._rblock_init(ks[i]) if kind == "rglru"
                            else self._ablock_init(ks[i]))
        return out

    def init(self, rng) -> Params:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        params: Params = {
            "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, self.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if self.n_groups:
            gks = jax.random.split(ks[1], self.n_groups)
            params["groups"] = jax.vmap(self._group_init)(gks)
        lks = jax.random.split(ks[2], max(self.n_leftover, 1))
        params["leftover"] = [
            (self._rblock_init(lks[i]) if self.pattern[i] == "rglru"
             else self._ablock_init(lks[i]))
            for i in range(self.n_leftover)]
        return params

    # ----------------------------------------------------------- block bodies
    def _mlp_apply(self, p, x):
        h = L.rms_norm(x, p["ln"], self.cfg.norm_eps)
        return x + L.ffn_apply(p, h)

    def _rblock_seq(self, p, x, impl):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        gate = jax.nn.gelu(h @ p["w_gate"])
        u = causal_conv1d(h @ p["w_in"], p["conv_w"])
        hr = rglru_sequence(p["rg"], u, impl=impl or "xla")
        x = x + (gate * hr) @ p["w_out"]
        return self._mlp_apply(p["mlp"], x)

    def _ablock_seq(self, p, x, positions, impl):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=self.head_dim, positions=positions,
                             rope_theta=cfg.rope_theta)
        o = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=cfg.local_attn_window,
                        impl=impl or cfg.attention_impl)
        x = x + L.attn_out(p["attn"], o)
        return self._mlp_apply(p["mlp"], x)

    def _group_seq(self, g, x, positions, impl):
        for i, kind in enumerate(self.pattern):
            if kind == "rglru":
                x = self._rblock_seq(g[f"b{i}"], x, impl)
            else:
                x = self._ablock_seq(g[f"b{i}"], x, positions, impl)
        return x

    # --------------------------------------------------------------- forward
    def forward(self, params: Params, tokens: jax.Array,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = params["embed"][tokens]
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def body(x, g):
            return self._group_seq(g, x, positions, None), None

        if remat:
            body = jax.checkpoint(body)
        if self.n_groups:
            x, _ = lax.scan(body, x, params["groups"])
        for i, p in enumerate(params["leftover"]):
            if self.pattern[i] == "rglru":
                x = self._rblock_seq(p, x, None)
            else:
                x = self._ablock_seq(p, x, positions, None)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["embed"].T, jnp.float32(0.0)

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = False) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               self.cfg.vocab_size,
                               mask=batch.get("loss_mask"))

    # ------------------------------------------------------------- KV / state
    def cache_capacity(self, max_len: int) -> int:
        return min(max_len, self.cfg.local_attn_window)

    def _attn_indices(self):
        return [i for i, k in enumerate(self.pattern) if k == "attn"]

    def cache_batch_axes(self, cache):
        return {k: (0 if (k == "length" or k.startswith("l")) else 1)
                for k in cache}

    def paged_kv_layout(self):
        """Hybrid blocks mix ring-buffer local attention with recurrent
        state — neither fits immutable pages; dense rows instead."""
        return None

    def extend_cache(self, cache, extra: int):
        keys = [k for k in cache if k.startswith("g") and
                (k.endswith("_k") or k.endswith("_v"))]
        if not keys:
            return cache
        T = cache[keys[0]].shape[2]
        target = self.cache_capacity(T + extra)
        if target <= T:
            return cache
        out = dict(cache)
        for key in keys:
            c = cache[key]
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, target - T)
            out[key] = jnp.pad(c, pad)
        return out

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        T = self.cache_capacity(max_len)
        n_attn = len(self._attn_indices())
        G, B = self.n_groups, batch
        cache: Dict[str, Any] = {"length": jnp.zeros((B,), jnp.int32)}
        for i, kind in enumerate(self.pattern):
            if kind == "rglru":
                cache[f"g{i}_lru"] = jnp.zeros((G, B, self.d_rnn), jnp.float32)
                cache[f"g{i}_conv"] = jnp.zeros(
                    (G, B, cfg.conv1d_width - 1, self.d_rnn), self.dtype)
            else:
                cache[f"g{i}_k"] = jnp.zeros(
                    (G, B, T, cfg.num_kv_heads, self.head_dim), self.dtype)
                cache[f"g{i}_v"] = jnp.zeros(
                    (G, B, T, cfg.num_kv_heads, self.head_dim), self.dtype)
        for j in range(self.n_leftover):
            cache[f"l{j}_lru"] = jnp.zeros((B, self.d_rnn), jnp.float32)
            cache[f"l{j}_conv"] = jnp.zeros(
                (B, cfg.conv1d_width - 1, self.d_rnn), self.dtype)
        return cache

    def _kv_slot_positions(self, pos: jax.Array, T: int) -> jax.Array:
        slots = jnp.arange(T, dtype=jnp.int32)[None, :]
        p = pos[:, None]
        q = p - ((p - slots) % T)
        return jnp.where((q >= 0) & (q <= p), q, -1)

    # --------------------------------------------------------------- prefill
    def prefill(self, params: Params, tokens: jax.Array,
                impl: Optional[str] = None) -> Tuple[jax.Array, Dict[str, Any]]:
        """Full prompt pass; returns last logits + recurrent/KV state."""
        cfg = self.cfg
        x = params["embed"][tokens]
        B, S, _ = x.shape
        T = self.cache_capacity(S)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def rblock_with_state(p, x, impl):
            h = L.rms_norm(x, p["ln"], cfg.norm_eps)
            gate = jax.nn.gelu(h @ p["w_gate"])
            u_in = h @ p["w_in"]
            u = causal_conv1d(u_in, p["conv_w"])
            a, b = rglru_gates(p["rg"], u)

            def combine(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, a2 * b1 + b2
            _, hr = lax.associative_scan(combine, (a, b), axis=1)
            x = x + (gate * hr.astype(x.dtype)) @ p["w_out"]
            st = (hr[:, -1],                                   # (B,D) f32
                  u_in[:, -(cfg.conv1d_width - 1):])           # conv buffer
            return self._mlp_apply(p["mlp"], x), st

        def ablock_with_state(p, x, impl):
            h = L.rms_norm(x, p["ln"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=cfg.rope_theta)
            o = L.attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            window=cfg.local_attn_window,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            return self._mlp_apply(p["mlp"], x), (k[:, S - T:], v[:, S - T:])

        def body(x, g):
            sts = {}
            for i, kind in enumerate(self.pattern):
                if kind == "rglru":
                    x, st = rblock_with_state(g[f"b{i}"], x, impl)
                    sts[f"g{i}_lru"], sts[f"g{i}_conv"] = st
                else:
                    x, st = ablock_with_state(g[f"b{i}"], x, impl)
                    sts[f"g{i}_k"], sts[f"g{i}_v"] = st
            return x, sts

        cache: Dict[str, Any] = {}
        if self.n_groups:
            x, sts = lax.scan(body, x, params["groups"])
            cache.update(sts)
        for j, p in enumerate(params["leftover"]):
            x, st = rblock_with_state(p, x, impl)
            cache[f"l{j}_lru"], cache[f"l{j}_conv"] = st
        cache["length"] = jnp.full((B,), S, jnp.int32)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x[:, -1] @ params["embed"].T, cache

    # ------------------------------------------------------------ decode step
    def decode_step(self, params: Params, token: jax.Array,
                    cache: Dict[str, Any],
                    impl: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        B = token.shape[0]
        pos = cache["length"]
        x = params["embed"][token]                             # (B,D)
        batch_ix = jnp.arange(B, dtype=jnp.int32)

        def rblock_step(p, x, lru, conv_buf):
            h = L.rms_norm(x[:, None], p["ln"], cfg.norm_eps)[:, 0]
            gate = jax.nn.gelu(h @ p["w_gate"])
            u_t, conv_buf = causal_conv1d_step(h @ p["w_in"], conv_buf,
                                               p["conv_w"])
            hr, lru = rglru_step(p["rg"], u_t, lru)
            x = x + (gate * hr) @ p["w_out"]
            h = L.rms_norm(x[:, None], p["mlp"]["ln"], cfg.norm_eps)[:, 0]
            return x + L.ffn_apply(p["mlp"], h), lru, conv_buf

        def ablock_step(p, x, k_c, v_c):
            T = k_c.shape[1]
            slot = (pos % T).astype(jnp.int32)
            kv_pos = self._kv_slot_positions(pos, T)
            h = L.rms_norm(x[:, None], p["ln"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim,
                                 positions=pos[:, None],
                                 rope_theta=cfg.rope_theta)
            k_c = k_c.at[batch_ix, slot].set(k[:, 0])
            v_c = v_c.at[batch_ix, slot].set(v[:, 0])
            o = L.attention(q, k_c, v_c, q_positions=pos[:, None],
                            kv_positions=kv_pos, causal=True,
                            window=cfg.local_attn_window,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)[:, 0]
            h = L.rms_norm(x[:, None], p["mlp"]["ln"], cfg.norm_eps)[:, 0]
            return x + L.ffn_apply(p["mlp"], h), k_c, v_c

        new_cache = dict(cache)

        def body(x, xs):
            g, st = xs
            new_st = dict(st)
            for i, kind in enumerate(self.pattern):
                if kind == "rglru":
                    x, lru, cb = rblock_step(g[f"b{i}"], x, st[f"g{i}_lru"],
                                             st[f"g{i}_conv"])
                    new_st[f"g{i}_lru"], new_st[f"g{i}_conv"] = lru, cb
                else:
                    x, k_c, v_c = ablock_step(g[f"b{i}"], x, st[f"g{i}_k"],
                                              st[f"g{i}_v"])
                    new_st[f"g{i}_k"], new_st[f"g{i}_v"] = k_c, v_c
            return x, new_st

        if self.n_groups:
            gstate = {k: v for k, v in cache.items() if k.startswith("g")}
            x, new_gstate = lax.scan(body, x, (params["groups"], gstate))
            new_cache.update(new_gstate)
        for j, p in enumerate(params["leftover"]):
            x, lru, cb = rblock_step(p, x, cache[f"l{j}_lru"],
                                     cache[f"l{j}_conv"])
            new_cache[f"l{j}_lru"], new_cache[f"l{j}_conv"] = lru, cb
        new_cache["length"] = pos + 1
        x = L.rms_norm(x[:, None], params["final_norm"], cfg.norm_eps)[:, 0]
        return x @ params["embed"].T, new_cache
