"""Model zoo registry: ``build_model(cfg)`` dispatches on ``cfg.family``.

All models implement the same protocol:
  init(rng) -> params
  forward(params, tokens, ...) -> (logits, aux)      # teacher-forced
  loss_fn(params, batch, remat=False) -> scalar loss
  init_cache(batch, max_len) -> cache pytree
  prefill(params, tokens, ...) -> (last_logits, cache)
  decode_step(params, token, cache) -> (logits, cache)
  cache_batch_axes(cache) -> {leaf: batch axis}      # row split/stack
  extend_cache(cache, extra) -> cache                # grow decode headroom
  paged_kv_layout() -> (layers, kv_heads, head_dim) | None

Models whose ``paged_kv_layout()`` is non-None additionally implement the
paged-KV hooks the continuous-batching engine drives (KV lives in a
refcounted DEVICE-RESIDENT ``PagedKVCache``):
  cache_kv_rows_dev(cache, row, len) -> (k, v) jnp   # page-store writes
  cache_kv_rows(cache, row) -> (k, v) float32 numpy  # migration staging
  prefill_with_cache(params, tokens, cache) -> (last_logits, cache)
  paged_decode_step(params, token, k_pages, v_pages, page_table, lengths)
      -> (logits, k_pages, v_pages)                  # decode from pages:
      in-pool KV scatter + paged-attention (Pallas kernel or XLA gather)
and the dense-view reference hooks (A/B path, models without the paged
step):
  paged_cache_view(k_rows, v_rows, lengths) -> cache # pages -> dense view
  decode_kv_taps(cache, slots) -> (k, v) numpy       # per-step page append
"""
from __future__ import annotations

from repro.configs.base import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.engine.models.transformer import TransformerLM
        return TransformerLM(cfg)
    if cfg.family == "audio":
        from repro.engine.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "ssm":
        from repro.engine.models.xlstm import XLSTMLM
        return XLSTMLM(cfg)
    if cfg.family == "hybrid":
        from repro.engine.models.rglru import GriffinLM
        return GriffinLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


__all__ = ["build_model"]
