"""Core neural-net layers shared by the model zoo (pure JAX, no flax).

Conventions
-----------
* params are nested dicts of jnp arrays; init fns take an rng and shape info.
* activations flow in ``cfg.dtype`` (bf16 by default); softmax / norms / the
  recurrence accumulators run in f32.
* attention has two implementations selected by ``cfg.attention_impl``:
  ``"xla"`` (reference einsum path used by the dry-run) and ``"pallas"``
  (TPU kernels from :mod:`repro.kernels`, validated in interpret mode).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(rng, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(rng, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                          # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# attention (XLA reference path; the Pallas kernels mirror this math)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_xla(
    q: jax.Array,                    # (B, Sq, H, Dh)
    k: jax.Array,                    # (B, Skv, Hkv, Dh)
    v: jax.Array,                    # (B, Skv, Hkv, Dh)
    *,
    q_positions: jax.Array,          # (B, Sq) int32
    kv_positions: jax.Array,         # (B, Skv) int32; -1 marks invalid slots
    causal: bool = True,
    window: int = 0,                 # 0 => unbounded
) -> jax.Array:
    # sequence-parallel hints re-applied PER CALL so they survive the
    # chunk scan's slicing (see _sp_attention_specs).  Only the QUERY-side
    # tensors are constrained here: KV is constrained once at the
    # dispatcher (hoisting the KV all-gather out of the chunk loop —
    # §Perf iteration B2 measured each chunk re-gathering its KV slice).
    sp = _sp_attention_specs(q, k) if q.shape[1] > 1 else None
    if sp is not None:
        q_spec, kv_spec = sp
        q = _constrain(q, q_spec)
        q_positions = _constrain(q_positions, q_spec[:2])
    B, Sq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, Dh)

    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(Dh)                                       # (B,Hkv,G,Sq,Skv)

    qp = q_positions[:, None, None, :, None]                # (B,1,1,Sq,1)
    kp = kv_positions[:, None, None, None, :]               # (B,1,1,1,Skv)
    mask = kp >= 0
    if causal:
        mask = mask & (kp <= qp)
    if window > 0:
        mask = mask & (kp > qp - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    out = out.reshape(B, Sq, H, Dh)
    if sp is not None:
        out = _constrain(out, sp[0])
    return out


# Above ~4M logits elements per (q-block × kv) tile, materializing the
# full (B,H,Sq,Skv) score tensor dominates HBM (train_4k: 17 GiB/device/
# layer; prefill_32k: TBs).  The chunked path scans query blocks so only
# one block's scores are ever live — the flash-attention recurrence
# expressed in pure XLA (the Pallas kernel is its TPU-native twin).
_CHUNK_TARGET_ELEMS = 4 * 1024 * 1024

# ---------------------------------------------------------------------------
# activation-sharding context (sequence-parallel attention)
#
# GQA head counts (8 kv heads) don't divide a 16-way model axis, and the
# chunked scan blocks the partitioner's own head-sharding propagation —
# the §Perf baseline shows attention running with FULL heads per device
# (16× redundant flops + TB-scale all-gathers).  The fix: constrain the
# QUERY TIME dim onto the model axis around attention (context/sequence
# parallelism — seq_len always divides the axis, for every arch), letting
# KV replicate across it (small for GQA).  Enabled by the launcher via
# set_activation_sharding(); REPRO_SP_ATTENTION=0 disables (hillclimb
# before/after).
# ---------------------------------------------------------------------------
import os as _os

# memspace: device (model arrays are device-resident jnp values)

_ACT_CTX: dict = {"mesh": None}


def set_activation_sharding(mesh, batch_axes=("data",), seq_axis="model"):
    """Install (or clear, with mesh=None) the activation-sharding hints."""
    if _os.environ.get("REPRO_SP_ATTENTION", "1") == "0":
        mesh = None
    _ACT_CTX["mesh"] = mesh
    _ACT_CTX["batch"] = tuple(batch_axes)
    _ACT_CTX["seq"] = seq_axis


def _constrain(x, spec_entries):
    mesh = _ACT_CTX.get("mesh")
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec_entries)))


def constrain_hidden(x):
    """Keep the residual stream (B,S,D) sequence-sharded between blocks
    (Megatron-SP): without this, every seq-sharded attention output is
    all-gathered back to a replicated hidden state — the §Perf baseline
    shows that gather dominating the collective term (4.3 GB × L per
    device for deepseek-67b prefill).  No-op when no mesh context or the
    seq dim doesn't divide."""
    mesh = _ACT_CTX.get("mesh")
    if mesh is None or x.ndim != 3 or x.shape[1] <= 1:
        return x
    seq_n = mesh.shape[_ACT_CTX["seq"]]
    if x.shape[1] % seq_n or x.shape[1] < seq_n:
        return x
    batch = _ACT_CTX["batch"]
    bn = 1
    for a in batch:
        bn *= mesh.shape[a]
    b_ent = batch if x.shape[0] % bn == 0 else None
    return _constrain(x, (b_ent, _ACT_CTX["seq"], None))


def _sp_attention_specs(q, k):
    """(q_spec, kv_spec) for sequence-parallel attention, or None."""
    mesh = _ACT_CTX.get("mesh")
    if mesh is None:
        return None
    seq_n = mesh.shape[_ACT_CTX["seq"]]
    batch = _ACT_CTX["batch"]
    bn = 1
    for a in batch:
        bn *= mesh.shape[a]
    b_ent = batch if q.shape[0] % bn == 0 else None
    if q.shape[1] % seq_n or q.shape[1] < seq_n:
        return None
    q_spec = (b_ent, _ACT_CTX["seq"], None, None)
    kv_spec = (b_ent, None, None, None)
    return q_spec, kv_spec


def _pick_q_block(sq: int, skv: int) -> int:
    """Query-block size for chunked attention.

    The budget is PER-DEVICE: under sequence-parallel sharding a global
    block of bq rows puts only bq/seq_n on each chip, so the global block
    can be seq_n× larger for the same VMEM/HBM footprint.  Larger blocks
    divide the number of KV re-reads (nq = Sq/bq), which the §Perf
    baseline showed dominating the memory roofline term (KV streamed
    256× per layer at 32k with the naive global budget).
    """
    mesh = _ACT_CTX.get("mesh")
    seq_n = mesh.shape[_ACT_CTX["seq"]] if mesh is not None else 1
    bq = max(_CHUNK_TARGET_ELEMS * seq_n // max(skv, 1), 128)
    while sq % bq:
        bq //= 2
        if bq < 2:
            return sq
    return min(bq, sq)


def attention_xla_chunked(q, k, v, *, q_positions, kv_positions,
                          causal=True, window=0, block_q: int = 0,
                          static_causal: bool = False):
    """Query-block-chunked attention; numerically identical math.

    ``static_causal`` (self-attention where positions are the standard
    arange — prefill/teacher-forced forward): unroll the chunk loop and
    statically slice the KV to each block's visible range
    [max(0, hi−window−bq), hi).  Skips the fully-masked upper triangle —
    ~2× attention flops/bytes for causal, ~Skv/window× for SWA (§Perf
    iteration 3).  The scan path handles arbitrary positions (ring
    buffers, padding).
    """
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    bq = block_q or _pick_q_block(Sq, Skv)
    if bq >= Sq:
        return attention_xla(q, k, v, q_positions=q_positions,
                             kv_positions=kv_positions, causal=causal,
                             window=window)
    nq = Sq // bq

    if static_causal and causal and Sq == Skv and nq <= 64:
        outs = []
        for i in range(nq):
            hi = (i + 1) * bq
            lo = max(0, hi - window - bq) if window > 0 else 0
            outs.append(attention_xla(
                q[:, i * bq:hi], k[:, lo:hi], v[:, lo:hi],
                q_positions=q_positions[:, i * bq:hi],
                kv_positions=kv_positions[:, lo:hi],
                causal=True, window=window))
        return jnp.concatenate(outs, axis=1)

    qr = q.reshape(B, nq, bq, H, Dh).swapaxes(0, 1)          # (nq,B,bq,H,Dh)
    qp = q_positions.reshape(B, nq, bq).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        qb, qpb = xs
        out = attention_xla(qb, k, v, q_positions=qpb,
                            kv_positions=kv_positions, causal=causal,
                            window=window)
        return carry, out

    _, outs = lax.scan(body, None, (qr, qp))
    return outs.swapaxes(0, 1).reshape(B, Sq, H, Dh)


def attention(q, k, v, *, q_positions, kv_positions, causal=True, window=0,
              impl: str = "xla"):
    """Dispatch between the XLA reference and the Pallas kernels."""
    if impl == "xla":
        sp = _sp_attention_specs(q, k) if q.shape[1] > 1 else None
        if sp is not None:
            # replicate KV across the seq-parallel axis ONCE, outside any
            # chunk loop (hoisted all-gather)
            k = _constrain(k, sp[1])
            v = _constrain(v, sp[1])
            kv_positions = _constrain(kv_positions, sp[1][:2])
        if q.shape[1] > 1 and q.shape[1] * k.shape[1] > _CHUNK_TARGET_ELEMS:
            # every Sq==Skv causal call in this codebase uses standard
            # arange positions, so the static triangle/window slicing
            # applies (ring-buffer/padded cases all have Sq != Skv)
            return attention_xla_chunked(
                q, k, v, q_positions=q_positions, kv_positions=kv_positions,
                causal=causal, window=window,
                static_causal=(causal and window == 0
                               and q.shape[1] == k.shape[1]
                               and _os.environ.get(
                                   "REPRO_STATIC_CAUSAL", "1") != "0"))
        return attention_xla(q, k, v, q_positions=q_positions,
                             kv_positions=kv_positions, causal=causal,
                             window=window)
    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        Sq = q.shape[1]
        if Sq == 1:
            from repro.kernels.decode_attention import ops as dec_ops
            return dec_ops.decode_attention(
                q, k, v, q_positions=q_positions, kv_positions=kv_positions,
                window=window, interpret=interpret)
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, q_positions=q_positions, kv_positions=kv_positions,
            causal=causal, window=window, interpret=interpret)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------

def attn_init(rng, d_model, num_heads, num_kv_heads, head_dim, dtype,
              qk_norm: bool = False):
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    return p


def attn_qkv(p, x, *, num_heads, num_kv_heads, head_dim, positions,
             rope_theta, qk_norm=False, use_rope=True, norm_eps=1e-6):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, num_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = (x @ p["wv"]).reshape(B, S, num_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_out(p, o):
    B, S, H, Dh = o.shape
    return o.reshape(B, S, H * Dh) @ p["wo"]


# ---------------------------------------------------------------------------
# feed-forward (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def ffn_init(rng, d_model, d_ff, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype),
    }


def ffn_apply(p, x, activation: str = "silu"):
    act = jax.nn.silu if activation == "silu" else jax.nn.gelu
    return (act(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def ffn_apply_nogate(p, x, activation: str = "gelu"):
    act = jax.nn.gelu if activation == "gelu" else jax.nn.silu
    return act(x @ p["w_up"]) @ p["w_down"]


# ---------------------------------------------------------------------------
# cross-entropy with padded vocab
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """logits: (..., V_pad); labels int32 (...); mask optional (...)."""
    vpad = logits.shape[-1]
    logits32 = logits.astype(jnp.float32)
    if vpad > vocab_size:
        pad_mask = jnp.arange(vpad, dtype=jnp.int32) < vocab_size
        logits32 = jnp.where(pad_mask, logits32, NEG_INF)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1)
        return (nll * mask).sum() / denom
    return nll.mean()
