"""xLSTM LM — alternating mLSTM / sLSTM blocks (arXiv:2405.04517).

Pure recurrent: decode keeps O(1) state per layer (this is why the
long_500k shape runs for this arch).  Training runs the recurrences with
``lax.scan`` over time (stabilized exponential gating in f32); decode uses
the same step function on carried state.

Block structure (paper Fig. 9/10, simplified where noted):
* mLSTM block: LN -> up-proj (2x, split u/z) -> causal conv(4) on u ->
  q,k from conv(u), v from u -> multi-head mLSTM -> group-norm -> *silu(z)
  -> down-proj -> residual.
* sLSTM block: LN -> headwise sLSTM with block-diagonal recurrent weights
  -> group-norm -> GeGLU up/down (factor 4/3) -> residual.  (No conv in the
  sLSTM block — matches the no-conv variants in the paper's ablations.)

State per layer pair: mLSTM (C: B,H,Dh,Dh; n: B,H,Dh; m: B,H; conv buffer)
and sLSTM (c,n,h,m: B,D).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.engine.models import layers as L

# memspace: device (model arrays are device-resident jnp values)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# causal depthwise conv (shared with rglru.py)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,S,D); w: (W,D) depthwise taps. Output (B,S,D)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(W):                         # W is tiny (4): unrolled
        out = out + pad[:, t:t + x.shape[1]] * w[t][None, None, :]
    return out


def causal_conv1d_step(x_t: jax.Array, buf: jax.Array, w: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """x_t: (B,D); buf: (B,W-1,D) previous inputs. Returns (y_t, new_buf)."""
    W = w.shape[0]
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)   # (B,W,D)
    y = jnp.einsum("bwd,wd->bd", window, w)
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# mLSTM cell (stabilized, recurrent form)
# ---------------------------------------------------------------------------

def mlstm_step(state, q, k, v, i_pre, f_pre):
    """One mLSTM step for all heads.

    state: (C (B,H,Dh,Dh), n (B,H,Dh), m (B,H))
    q,k,v: (B,H,Dh); i_pre,f_pre: (B,H) pre-activations.
    """
    C, n, m = state
    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))       # (B,H)
    i_t = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, i_t)
    f_sc = jnp.exp(log_f + m - m_new)[..., None]                # (B,H,1)
    i_sc = jnp.exp(i_t - m_new)[..., None]
    k32, v32, q32 = (a.astype(jnp.float32) for a in (k, v, q))
    C = f_sc[..., None] * C + i_sc[..., None] * (v32[..., :, None] * k32[..., None, :])
    n = f_sc * n + i_sc * k32
    num = jnp.einsum("bhij,bhj->bhi", C, q32)                   # (B,H,Dh)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q32)),
                      jnp.exp(-m_new))[..., None]
    h = num / den
    return (C, n, m_new), h


def mlstm_sequence(q, k, v, i_pre, f_pre, state):
    """q,k,v: (B,S,H,Dh); gates (B,S,H). Scan over time."""
    def body(st, xs):
        qt, kt, vt, it, ft = xs
        st, h = mlstm_step(st, qt, kt, vt, it, ft)
        return st, h
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    state, hs = lax.scan(body, state, xs)                       # hs: (S,B,H,Dh)
    return state, hs.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# sLSTM cell (stabilized, headwise recurrent weights)
# ---------------------------------------------------------------------------

def slstm_step(state, x_gates, r_w):
    """state: (c,n,h,m) each (B,D); x_gates: (B,4D) [z,i,f,o] pre-acts from x;
    r_w: (4, H, Dh, Dh) block-diagonal recurrent weights."""
    c, n, h, m = state
    B, D = c.shape
    H, Dh = r_w.shape[1], r_w.shape[2]
    hh = h.reshape(B, H, Dh).astype(jnp.float32)
    rec = jnp.einsum("bhi,ghij->gbhj", hh, r_w.astype(jnp.float32))
    rec = rec.reshape(4, B, D)
    zx, ix, fx, ox = jnp.split(x_gates.astype(jnp.float32), 4, axis=-1)
    z_t = jnp.tanh(zx + rec[0])
    i_t = ix + rec[1]
    f_t = fx + rec[2]
    o_t = jax.nn.sigmoid(ox + rec[3])
    log_f = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(log_f + m, i_t)
    f_sc = jnp.exp(log_f + m - m_new)
    i_sc = jnp.exp(i_t - m_new)
    c_new = f_sc * c + i_sc * z_t
    n_new = f_sc * n + i_sc
    h_new = o_t * (c_new / jnp.maximum(n_new, 1e-6))
    return (c_new, n_new, h_new, m_new), h_new


def slstm_sequence(x_gates, r_w, state):
    """x_gates: (B,S,4D). Scan over time."""
    def body(st, xg):
        st, h = slstm_step(st, xg, r_w)
        return st, h
    state, hs = lax.scan(body, state, x_gates.swapaxes(0, 1))
    return state, hs.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)
        self.H = cfg.num_heads
        self.Dh = cfg.resolved_head_dim
        self.d_inner = self.H * self.Dh                    # mLSTM inner width
        pattern = cfg.block_pattern or ("mlstm", "slstm")
        assert pattern == ("mlstm", "slstm"), "xLSTM uses (mlstm, slstm) pairs"
        assert cfg.num_layers % 2 == 0
        self.n_pairs = cfg.num_layers // 2

    # ------------------------------------------------------------------ init
    def _pair_init(self, rng):
        cfg = self.cfg
        d, di = cfg.d_model, self.d_inner
        ks = jax.random.split(rng, 10)
        mlstm = {
            "ln": jnp.zeros((d,), self.dtype),
            "w_up": L.dense_init(ks[0], d, 2 * di, self.dtype),
            "conv_w": (jax.random.normal(ks[1], (cfg.conv1d_width, di),
                                         jnp.float32) * 0.1).astype(self.dtype),
            "wq": L.dense_init(ks[2], di, di, self.dtype),
            "wk": L.dense_init(ks[3], di, di, self.dtype),
            "wv": L.dense_init(ks[4], di, di, self.dtype),
            "w_if": L.dense_init(ks[5], di, 2 * self.H, self.dtype),
            "gn": jnp.zeros((di,), self.dtype),
            "w_down": L.dense_init(ks[6], di, d, self.dtype),
        }
        dff = max((4 * d) // 3, 8)
        slstm = {
            "ln": jnp.zeros((d,), self.dtype),
            "w_gates": L.dense_init(ks[7], d, 4 * d, self.dtype),
            "r_w": (jax.random.normal(
                ks[8], (4, self.H, d // self.H, d // self.H), jnp.float32)
                * (1.0 / jnp.sqrt(d / self.H))).astype(self.dtype),
            "gn": jnp.zeros((d,), self.dtype),
            "w_up": L.dense_init(ks[9], d, 2 * dff, self.dtype),
            "w_down": L.dense_init(jax.random.fold_in(rng, 7), dff, d, self.dtype),
        }
        return {"mlstm": mlstm, "slstm": slstm}

    def init(self, rng) -> Params:
        cfg = self.cfg
        ks = jax.random.split(rng, 3)
        pair_ks = jax.random.split(ks[1], self.n_pairs)
        return {
            "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, self.dtype),
            "pairs": jax.vmap(self._pair_init)(pair_ks),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }

    # ------------------------------------------------------------- state init
    def _pair_state(self, batch: int):
        cfg = self.cfg
        f32 = jnp.float32
        return {
            "m_C": jnp.zeros((batch, self.H, self.Dh, self.Dh), f32),
            "m_n": jnp.zeros((batch, self.H, self.Dh), f32),
            "m_m": jnp.zeros((batch, self.H), f32),
            "m_conv": jnp.zeros((batch, cfg.conv1d_width - 1, self.d_inner),
                                self.dtype),
            "s_c": jnp.zeros((batch, cfg.d_model), f32),
            "s_n": jnp.zeros((batch, cfg.d_model), f32),
            "s_h": jnp.zeros((batch, cfg.d_model), f32),
            "s_m": jnp.zeros((batch, cfg.d_model), f32),
        }

    def cache_batch_axes(self, cache):
        return {k: (0 if k == "length" else 1) for k in cache}

    def paged_kv_layout(self):
        """O(1) recurrent state has no KV to page; the engine batches
        per-sequence state rows instead."""
        return None

    def extend_cache(self, cache, extra: int):
        return cache                    # O(1) recurrent state — nothing to grow

    def init_cache(self, batch: int, max_len: int = 0) -> Dict[str, Any]:
        state = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_pairs,) + x.shape),
            self._pair_state(batch))
        state["length"] = jnp.zeros((batch,), jnp.int32)
        return state

    # ----------------------------------------------------------- block bodies
    def _mlstm_block_seq(self, p, x, st):
        cfg = self.cfg
        B, S, _ = x.shape
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        up = h @ p["w_up"]
        u, z = jnp.split(up, 2, axis=-1)                     # (B,S,di)
        cu = causal_conv1d(u, p["conv_w"])
        cu = jax.nn.silu(cu)
        q = (cu @ p["wq"]).reshape(B, S, self.H, self.Dh) / jnp.sqrt(
            jnp.float32(self.Dh)).astype(self.dtype)
        k = (cu @ p["wk"]).reshape(B, S, self.H, self.Dh)
        v = (u @ p["wv"]).reshape(B, S, self.H, self.Dh)
        gates = cu @ p["w_if"]                               # (B,S,2H)
        i_pre, f_pre = jnp.split(gates, 2, axis=-1)
        mstate = (st["m_C"], st["m_n"], st["m_m"])
        mstate, hs = mlstm_sequence(q, k, v, i_pre, f_pre, mstate)
        hs = hs.reshape(B, S, self.d_inner).astype(self.dtype)
        hs = L.rms_norm(hs, p["gn"], cfg.norm_eps)           # group-norm proxy
        out = (hs * jax.nn.silu(z)) @ p["w_down"]
        new_st = dict(st)
        new_st["m_C"], new_st["m_n"], new_st["m_m"] = mstate
        new_st["m_conv"] = jnp.concatenate(
            [st["m_conv"], u], axis=1)[:, -(cfg.conv1d_width - 1):]
        return x + out, new_st

    def _slstm_block_seq(self, p, x, st):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln"], cfg.norm_eps)
        x_gates = h @ p["w_gates"]
        sstate = (st["s_c"], st["s_n"], st["s_h"], st["s_m"])
        (c, n, hh, m), hs = slstm_sequence(x_gates, p["r_w"], sstate)
        hs = L.rms_norm(hs.astype(self.dtype), p["gn"], cfg.norm_eps)
        g, up = jnp.split(hs @ p["w_up"], 2, axis=-1)
        out = (jax.nn.gelu(g) * up) @ p["w_down"]
        new_st = dict(st)
        new_st["s_c"], new_st["s_n"], new_st["s_h"], new_st["s_m"] = c, n, hh, m
        return x + out, new_st

    # --------------------------------------------------------------- forward
    def forward(self, params: Params, tokens: jax.Array,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = params["embed"][tokens]
        B = x.shape[0]
        init_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_pairs,) + a.shape),
            self._pair_state(B))

        def body(x, xs):
            p, st = xs
            x, st = self._mlstm_block_seq(p["mlstm"], x, st)
            x, st = self._slstm_block_seq(p["slstm"], x, st)
            return x, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, (params["pairs"], init_state))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x @ params["embed"].T
        return logits, jnp.float32(0.0)

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = False) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"], remat=remat)
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                               self.cfg.vocab_size,
                               mask=batch.get("loss_mask"))

    # --------------------------------------------------------------- prefill
    def prefill(self, params: Params, tokens: jax.Array,
                impl: Optional[str] = None) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        x = params["embed"][tokens]
        B, S, _ = x.shape
        init_state = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_pairs,) + a.shape),
            self._pair_state(B))

        def body(x, xs):
            p, st = xs
            x, st = self._mlstm_block_seq(p["mlstm"], x, st)
            x, st = self._slstm_block_seq(p["slstm"], x, st)
            return x, st

        x, states = lax.scan(body, x, (params["pairs"], init_state))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = x[:, -1] @ params["embed"].T
        states["length"] = jnp.full((B,), S, jnp.int32)
        return logits, states

    # ------------------------------------------------------------ decode step
    def decode_step(self, params: Params, token: jax.Array,
                    cache: Dict[str, Any],
                    impl: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        B = token.shape[0]
        x = params["embed"][token]                            # (B,D)

        def pair_step(x, xs):
            p, st = xs
            new_st = dict(st)
            # ---- mLSTM block, single step
            mp = p["mlstm"]
            h = L.rms_norm(x[:, None], mp["ln"], cfg.norm_eps)[:, 0]
            u, z = jnp.split(h @ mp["w_up"], 2, axis=-1)
            cu, conv_buf = causal_conv1d_step(u, st["m_conv"], mp["conv_w"])
            cu = jax.nn.silu(cu)
            q = (cu @ mp["wq"]).reshape(B, self.H, self.Dh) / jnp.sqrt(
                jnp.float32(self.Dh)).astype(self.dtype)
            k = (cu @ mp["wk"]).reshape(B, self.H, self.Dh)
            v = (u @ mp["wv"]).reshape(B, self.H, self.Dh)
            i_pre, f_pre = jnp.split(cu @ mp["w_if"], 2, axis=-1)
            mstate = (st["m_C"], st["m_n"], st["m_m"])
            mstate, hm = mlstm_step(mstate, q, k, v, i_pre, f_pre)
            hm = hm.reshape(B, self.d_inner).astype(self.dtype)
            hm = L.rms_norm(hm[:, None], mp["gn"], cfg.norm_eps)[:, 0]
            x = x + (hm * jax.nn.silu(z)) @ mp["w_down"]
            new_st["m_C"], new_st["m_n"], new_st["m_m"] = mstate
            new_st["m_conv"] = conv_buf
            # ---- sLSTM block, single step
            sp = p["slstm"]
            h = L.rms_norm(x[:, None], sp["ln"], cfg.norm_eps)[:, 0]
            sstate = (st["s_c"], st["s_n"], st["s_h"], st["s_m"])
            (c, n, hh, m), hs = slstm_step(sstate, h @ sp["w_gates"], sp["r_w"])
            hs = L.rms_norm(hs.astype(self.dtype)[:, None], sp["gn"],
                            cfg.norm_eps)[:, 0]
            g, up = jnp.split(hs @ sp["w_up"], 2, axis=-1)
            x = x + (jax.nn.gelu(g) * up) @ sp["w_down"]
            new_st["s_c"], new_st["s_n"], new_st["s_h"], new_st["s_m"] = c, n, hh, m
            return x, new_st

        length = cache.pop("length")
        x, new_states = lax.scan(pair_step, x, (params["pairs"], cache))
        cache["length"] = length                              # restore caller's
        new_states["length"] = length + 1
        x = L.rms_norm(x[:, None], params["final_norm"], cfg.norm_eps)[:, 0]
        return x @ params["embed"].T, new_states
