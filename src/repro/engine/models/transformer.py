"""Decoder-only transformer LM (dense / MoE / SWA / qk-norm / VLM prefix).

Covers: deepseek-67b, llama3.2-3b, qwen3-1.7b/8b (dense, GQA, qk-norm),
deepseek-moe-16b (fine-grained MoE + shared experts, first layer dense),
mixtral-8x22b (MoE top-2 + sliding-window attention), internvl2-2b
(VLM backbone — precomputed patch embeddings prepended; frontend stubbed).

Layers are scanned (stacked params) so 95-layer models lower to O(1) HLO.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.engine.models import layers as L
from repro.engine.models import moe as M

# memspace: device (model arrays are device-resident jnp values)

Params = Dict[str, Any]


class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.head_dim = cfg.resolved_head_dim
        self.dtype = jnp.dtype(cfg.dtype)
        m = cfg.moe
        self.n_lead = m.first_dense_layers if m else 0     # unscanned lead layers
        self.n_scan = cfg.num_layers - self.n_lead

    # ------------------------------------------------------------------ init
    def _block_init(self, rng, lead: bool):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        p = {
            "ln1": jnp.zeros((cfg.d_model,), self.dtype),
            "ln2": jnp.zeros((cfg.d_model,), self.dtype),
            "attn": L.attn_init(k1, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, self.head_dim, self.dtype,
                                qk_norm=cfg.qk_norm),
        }
        if cfg.moe is not None and not lead:
            p["moe"] = M.moe_init(k2, cfg.d_model, cfg.moe, self.dtype)
        else:
            d_ff = (cfg.moe.d_ff_dense if (cfg.moe and lead) else cfg.d_ff)
            p["ffn"] = L.ffn_init(k3, cfg.d_model, d_ff, self.dtype)
        return p

    def init(self, rng) -> Params:
        cfg = self.cfg
        ks = jax.random.split(rng, 4)
        params: Params = {
            "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, self.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(ks[1], cfg.d_model,
                                             cfg.padded_vocab, self.dtype)
        if self.n_lead:
            lead_ks = jax.random.split(ks[2], self.n_lead)
            params["lead_blocks"] = [self._block_init(k, lead=True)
                                     for k in lead_ks]
        scan_ks = jax.random.split(ks[3], self.n_scan)
        params["blocks"] = jax.vmap(
            functools.partial(self._block_init, lead=False))(scan_ks)
        return params

    # ----------------------------------------------------------------- block
    def _block(self, p, x, positions, aux, *, impl=None):
        cfg = self.cfg
        impl = impl or cfg.attention_impl
        if cfg.family == "dense":
            # sequence-parallel residual stream (§Perf A5/B3).  Gated to
            # the dense family: MoE dispatch and VLM prefix concat fight
            # the seq-sharded layout (measured regressions, EXPERIMENTS).
            x = L.constrain_hidden(x)
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=self.head_dim, positions=positions,
                             rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                             norm_eps=cfg.norm_eps)
        o = L.attention(q, k, v, q_positions=positions, kv_positions=positions,
                        causal=True, window=cfg.swa_window, impl=impl)
        x = x + L.attn_out(p["attn"], o)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, a = M.moe_ffn(h, p["moe"], cfg.moe)
            aux = aux + a
        else:
            y = L.ffn_apply(p["ffn"], h)
        return x + y, aux

    # --------------------------------------------------------------- forward
    def forward(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None,
                remat: bool = False) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forced forward. Returns (logits (B,S,Vpad), aux_loss)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        aux = jnp.float32(0.0)

        for p in params.get("lead_blocks", []):
            x, aux = self._block(p, x, positions, aux)

        def body(carry, p):
            x, aux = carry
            x, aux = self._block(p, x, positions, aux)
            return (x, aux), None

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = lax.scan(body, (x, aux), params["blocks"])

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = x @ head
        return logits, aux

    def loss_fn(self, params: Params, batch: Dict[str, jax.Array],
                remat: bool = False) -> jax.Array:
        cfg = self.cfg
        logits, aux = self.forward(params, batch["tokens"],
                                   prefix_embeds=batch.get("patch_embeds"),
                                   remat=remat)
        labels, mask = batch["labels"], batch.get("loss_mask")
        if batch.get("patch_embeds") is not None:
            npat = batch["patch_embeds"].shape[1]
            logits = logits[:, npat:]
        ce = L.cross_entropy(logits[:, :-1], labels[:, 1:], cfg.vocab_size,
                             mask=None if mask is None else mask[:, 1:])
        weight = cfg.moe.router_aux_weight if cfg.moe else 0.0
        return ce + weight * aux

    # ------------------------------------------------------------- KV cache
    def cache_batch_axes(self, cache):
        """Batch axis per cache leaf (for tiling/splitting request batches)."""
        return {k: (0 if k == "length" else 1) for k in cache}

    # ------------------------------------------------- paged-KV engine hooks
    def paged_kv_layout(self) -> Optional[Tuple[int, int, int]]:
        """(layers, kv_heads, head_dim) for a PagedKVCache backing this
        model's KV, or None when pages can't back it (SWA ring buffers
        wrap in place, which fights immutable full pages)."""
        if self.cfg.swa_window:
            return None
        return (self.cfg.num_layers, self.cfg.num_kv_heads, self.head_dim)

    def cache_kv_rows_dev(self, cache, row: int, length: int):
        """One sequence's KV from a dense cache as DEVICE arrays
        ``(L_total, length, Hkv, Dh)`` — lead layers first, then scanned.
        This is the page-store write format: the device-resident pool
        scatters these rows into pages without a host round-trip
        (``length`` is passed by the caller so no device sync is needed
        to read ``cache['length']``)."""
        ks = [cache["k"][:, row, :length]]
        vs = [cache["v"][:, row, :length]]
        if "lead_k" in cache:
            ks.insert(0, cache["lead_k"][:, row, :length])
            vs.insert(0, cache["lead_v"][:, row, :length])
        k = jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0]
        v = jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0]
        return k, v

    def cache_kv_rows(self, cache, row: int):
        """Host (float32 numpy) variant of :meth:`cache_kv_rows_dev` —
        the migration wire format (exact for bf16)."""
        ln = int(cache["length"][row])
        k, v = self.cache_kv_rows_dev(cache, row, ln)
        return (np.asarray(k, dtype=np.float32),
                np.asarray(v, dtype=np.float32))

    def paged_cache_view(self, k_rows, v_rows, lengths):
        """Materialize the dense decode cache from gathered page rows.

        k_rows/v_rows: float32 numpy ``(B, L_total, T, Hkv, Dh)`` (zero-
        padded past each row's length); lengths: per-row token counts.
        The float32→model-dtype cast is exact for bf16 page contents.
        """
        k = jnp.asarray(k_rows, self.dtype).swapaxes(0, 1)  # (L,B,T,H,D)
        v = jnp.asarray(v_rows, self.dtype).swapaxes(0, 1)
        cache = {"k": k[self.n_lead:], "v": v[self.n_lead:],
                 "length": jnp.asarray(lengths, jnp.int32)}
        if self.n_lead:
            cache["lead_k"] = k[:self.n_lead]
            cache["lead_v"] = v[:self.n_lead]
        return cache

    def decode_kv_taps(self, cache, slots):
        """KV written at per-row ``slots`` (the last decode step's token)
        as float32 numpy ``(L_total, B, Hkv, Dh)`` — the page-append
        payload mirroring one `decode_step`."""
        ix = jnp.asarray(slots, jnp.int32)[None, :, None, None, None]

        def tap(a):                                   # (L,B,T,H,D)->(L,B,H,D)
            idx = jnp.broadcast_to(ix, a.shape[:2] + (1,) + a.shape[3:])
            return jnp.take_along_axis(a, idx, axis=2)[:, :, 0]

        ks = [tap(cache["k"])]
        vs = [tap(cache["v"])]
        if "lead_k" in cache:
            ks.insert(0, tap(cache["lead_k"]))
            vs.insert(0, tap(cache["lead_v"]))
        k = jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0]
        v = jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0]
        return (np.asarray(k, dtype=np.float32),
                np.asarray(v, dtype=np.float32))

    def cache_capacity(self, max_len: int) -> int:
        cfg = self.cfg
        return min(max_len, cfg.swa_window) if cfg.swa_window else max_len

    def extend_cache(self, cache, extra: int):
        """Grow the KV time axis by ``extra`` zero slots (decode headroom).

        Ring-buffer (SWA) caches already at window capacity are returned
        unchanged — the ring slot logic handles wrap-around.
        """
        T = cache["k"].shape[2]
        target = self.cache_capacity(T + extra)
        if target <= T:
            return cache
        pad = target - T
        out = dict(cache)
        for key in ("k", "v", "lead_k", "lead_v"):
            if key in cache:
                c = cache[key]
                cfgpad = [(0, 0)] * c.ndim
                cfgpad[2] = (0, pad)
                out[key] = jnp.pad(c, cfgpad)
        return out

    def init_cache(self, batch: int, max_len: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        T = self.cache_capacity(max_len)
        shape = (self.n_scan, batch, T, cfg.num_kv_heads, self.head_dim)
        cache = {
            "k": jnp.zeros(shape, self.dtype),
            "v": jnp.zeros(shape, self.dtype),
            "length": jnp.zeros((batch,), jnp.int32),
        }
        if self.n_lead:
            lshape = (self.n_lead,) + shape[1:]
            cache["lead_k"] = jnp.zeros(lshape, self.dtype)
            cache["lead_v"] = jnp.zeros(lshape, self.dtype)
        return cache

    def _kv_slot_positions(self, pos: jax.Array, T: int) -> jax.Array:
        """Absolute position stored in each cache slot when writing at `pos`.

        pos: (B,). Returns (B, T) with -1 for empty slots.  For ring buffers
        (SWA) slot s holds the newest position q ≡ s (mod T), q <= pos.
        """
        slots = jnp.arange(T, dtype=jnp.int32)[None, :]
        p = pos[:, None]
        if self.cfg.swa_window and self.cfg.swa_window == T:
            q = p - ((p - slots) % T)
        else:
            q = slots
        return jnp.where((q >= 0) & (q <= p), q, -1)

    # --------------------------------------------------------------- prefill
    def prefill(self, params: Params, tokens: jax.Array,
                prefix_embeds: Optional[jax.Array] = None,
                impl: Optional[str] = None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Run the full prompt; return (last-position logits, filled cache)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        T = self.cache_capacity(S)

        def run_block(p, x):
            if cfg.family == "dense":      # sequence-parallel residual (SP)
                x = L.constrain_hidden(x)
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                 norm_eps=cfg.norm_eps)
            o = L.attention(q, k, v, q_positions=positions,
                            kv_positions=positions, causal=True,
                            window=cfg.swa_window, impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if "moe" in p:
                y, _ = M.moe_ffn(h, p["moe"], cfg.moe)
            else:
                y = L.ffn_apply(p["ffn"], h)
            # keep only the cache window (T divides S for all assigned shapes)
            return x + y, (k[:, S - T:], v[:, S - T:])

        lead_kv = []
        for p in params.get("lead_blocks", []):
            x, kv = run_block(p, x)
            lead_kv.append(kv)

        def body(x, p):
            x, kv = run_block(p, x)
            return x, kv

        x, (ks, vs) = lax.scan(body, x, params["blocks"])   # ks: (L,B,T,Hkv,Dh)

        cache = {
            "k": ks, "v": vs,
            "length": jnp.full((B,), S, jnp.int32),
        }
        if lead_kv:
            cache["lead_k"] = jnp.stack([kv[0] for kv in lead_kv])
            cache["lead_v"] = jnp.stack([kv[1] for kv in lead_kv])
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return x[:, -1] @ head, cache

    # ----------------------------------------------------- chunked prefill
    def prefill_with_cache(self, params: Params, tokens: jax.Array,
                           cache: Dict[str, jax.Array],
                           impl: Optional[str] = None,
                           valid_len: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Prefill ``tokens`` (B, S_suf) as a continuation of ``cache``.

        The chunk's queries attend to the cached KV (a reused prefix, in
        the engine: gathered from shared pages) plus the chunk itself;
        the chunk's KV is written into the cache at its absolute slots.
        Full-attention caches only (slot s holds position s), so the
        result is bitwise what a monolithic ``prefill`` of prefix+chunk
        would produce for these positions.

        ``valid_len`` (B,) marks the REAL chunk length when ``tokens``
        is right-padded to a bucketed shape (the engine pads suffixes so
        timing-dependent prefix-share points reuse one compiled step).
        Causal attention keeps pad rows out of every real row's result;
        logits are read at ``valid_len - 1`` and the cache length
        advances by ``valid_len``, so padding is bitwise-invisible.
        """
        cfg = self.cfg
        assert not cfg.swa_window, "chunked prefill needs full attention"
        B, Ssuf = tokens.shape
        pos0 = cache["length"]                               # (B,)
        x = params["embed"][tokens]
        positions = pos0[:, None] + jnp.arange(Ssuf, dtype=jnp.int32)[None, :]
        T = cache["k"].shape[2]
        arange_t = jnp.arange(T, dtype=jnp.int32)[None, :]
        kv_pos = jnp.where(arange_t < (pos0 + Ssuf)[:, None], arange_t, -1)
        batch_ix = jnp.arange(B, dtype=jnp.int32)[:, None]

        def run_block(p, x, k_cache, v_cache):
            if cfg.family == "dense":      # sequence-parallel residual (SP)
                x = L.constrain_hidden(x)
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim, positions=positions,
                                 rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                 norm_eps=cfg.norm_eps)
            k_cache = k_cache.at[batch_ix, positions].set(k)
            v_cache = v_cache.at[batch_ix, positions].set(v)
            o = L.attention(q, k_cache, v_cache, q_positions=positions,
                            kv_positions=kv_pos, causal=True, window=0,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if "moe" in p:
                y, _ = M.moe_ffn(h, p["moe"], cfg.moe)
            else:
                y = L.ffn_apply(p["ffn"], h)
            return x + y, k_cache, v_cache

        new_cache = dict(cache)
        if self.n_lead:
            lk, lv = [], []
            for i, p in enumerate(params["lead_blocks"]):
                x, k_c, v_c = run_block(p, x, cache["lead_k"][i],
                                        cache["lead_v"][i])
                lk.append(k_c)
                lv.append(v_c)
            new_cache["lead_k"] = jnp.stack(lk)
            new_cache["lead_v"] = jnp.stack(lv)

        def body(x, xs):
            p, k_c, v_c = xs
            x, k_c, v_c = run_block(p, x, k_c, v_c)
            return x, (k_c, v_c)

        x, (ks, vs) = lax.scan(body, x,
                               (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs
        new_cache["length"] = pos0 + (Ssuf if valid_len is None
                                      else valid_len)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        if valid_len is None:
            return x[:, -1] @ head, new_cache
        last = jnp.take_along_axis(
            x, (valid_len - 1).astype(jnp.int32)[:, None, None], axis=1)[:, 0]
        return last @ head, new_cache

    # ------------------------------------------------------------ decode step
    def decode_step(self, params: Params, token: jax.Array,
                    cache: Dict[str, jax.Array],
                    impl: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """token: (B,) int32. One autoregressive step; updates cache in place."""
        cfg = self.cfg
        B = token.shape[0]
        pos = cache["length"]                                  # (B,)
        x = params["embed"][token][:, None, :]                 # (B,1,D)
        T = cache["k"].shape[2]
        slot = (pos % T).astype(jnp.int32)
        kv_pos = self._kv_slot_positions(pos, T)               # (B,T)
        batch_ix = jnp.arange(B, dtype=jnp.int32)

        def step_block(p, x, k_cache, v_cache):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim,
                                 positions=pos[:, None],
                                 rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                 norm_eps=cfg.norm_eps)
            k_cache = k_cache.at[batch_ix, slot].set(k[:, 0])
            v_cache = v_cache.at[batch_ix, slot].set(v[:, 0])
            o = L.attention(q, k_cache, v_cache, q_positions=pos[:, None],
                            kv_positions=kv_pos, causal=True,
                            window=cfg.swa_window,
                            impl=impl or cfg.attention_impl)
            x = x + L.attn_out(p["attn"], o)
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if "moe" in p:
                y, _ = M.moe_ffn(h, p["moe"], cfg.moe)
            else:
                y = L.ffn_apply(p["ffn"], h)
            return x + y, k_cache, v_cache

        new_cache = dict(cache)
        if self.n_lead:
            lk, lv = [], []
            for i, p in enumerate(params["lead_blocks"]):
                x, k_c, v_c = step_block(p, x, cache["lead_k"][i],
                                         cache["lead_v"][i])
                lk.append(k_c)
                lv.append(v_c)
            new_cache["lead_k"] = jnp.stack(lk)
            new_cache["lead_v"] = jnp.stack(lv)

        def body(x, xs):
            p, k_c, v_c = xs
            x, k_c, v_c = step_block(p, x, k_c, v_c)
            return x, (k_c, v_c)

        x, (ks, vs) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs
        new_cache["length"] = pos + 1

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return (x[:, -1] @ head), new_cache

    # ----------------------------------------------------- paged decode step
    def paged_decode_step(self, params: Params, token: jax.Array,
                          k_pages: jax.Array, v_pages: jax.Array,
                          page_table: jax.Array, lengths: jax.Array,
                          impl: Optional[str] = None,
                          variant: Optional[str] = None
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """One autoregressive step straight over the device-resident page
        pool — no dense KV view exists anywhere.

        token: (B,) int32; k_pages/v_pages: the pool, (L_total, P, page,
        Hkv, Dh); page_table: (B, n_pages) int32 (each row's pages in
        sequence order, zero-padded); lengths: (B,) int32 with -1 for
        padded rows.  Each layer lands the new token's KV at
        ``(page_table[b, len//page], len % page)`` and attends over the
        row's pages.  Under the Pallas impls the kernel ``variant``
        (None = the autotune table, see
        ``kernels/paged_decode_attention/ops.py``) picks how: ``fused``
        appends INSIDE the attention ``pallas_call`` (no separate
        scatter dispatch, no extra pool round-trip per layer);
        ``single``/``blocked`` scatter first, then attend.  The XLA
        fallback scatters and gathers densely.  Padded rows write
        nothing and are fully masked.  Returns ``(logits (B, Vpad),
        new_k_pages, new_v_pages)``; the caller adopts the returned
        pool arrays (donated under jit).
        """
        cfg = self.cfg
        impl = impl or cfg.attention_impl
        B = token.shape[0]
        P, ps = k_pages.shape[1], k_pages.shape[2]
        T = page_table.shape[1] * ps
        pos = lengths                                        # (B,)
        valid = pos >= 0
        posc = jnp.maximum(pos, 0)
        x = params["embed"][token][:, None, :]               # (B,1,D)
        write_page = jnp.take_along_axis(
            page_table, (posc // ps)[:, None], axis=1)[:, 0]
        write_page = jnp.where(valid, write_page, P)         # OOB -> dropped
        write_off = posc % ps
        t_idx = jnp.arange(T, dtype=jnp.int32)
        kv_pos = jnp.where(t_idx[None, :] <= pos[:, None], t_idx[None, :], -1)

        use_pallas = impl in ("pallas", "pallas_interpret")
        if use_pallas:
            from repro.kernels.paged_decode_attention.ops import (
                fused_paged_decode_attention, kernel_config,
                paged_decode_attention)
            kc = kernel_config(ps, cfg.num_kv_heads, self.head_dim,
                               cfg.num_heads // cfg.num_kv_heads)
            eff_variant = variant or kc["variant"]

        def step_block(p, x, kp_l, vp_l):
            h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
            q, k, v = L.attn_qkv(p["attn"], h, num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads,
                                 head_dim=self.head_dim,
                                 positions=posc[:, None],
                                 rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                 norm_eps=cfg.norm_eps)
            if use_pallas and eff_variant == "fused":
                # append+attend in ONE dispatch: the kernel writes the
                # new KV rows into their (page, offset) slots before any
                # page is read, so the scatter below never runs
                o, kp_l, vp_l = fused_paged_decode_attention(
                    q, kp_l, vp_l, page_table, pos,
                    k[:, 0].astype(kp_l.dtype), v[:, 0].astype(vp_l.dtype),
                    interpret=impl == "pallas_interpret")
                x = x + L.attn_out(p["attn"], o)
                h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
                if "moe" in p:
                    y, _ = M.moe_ffn(h, p["moe"], cfg.moe)
                else:
                    y = L.ffn_apply(p["ffn"], h)
                return x + y, kp_l, vp_l
            kp_l = kp_l.at[write_page, write_off].set(
                k[:, 0].astype(kp_l.dtype), mode="drop")
            vp_l = vp_l.at[write_page, write_off].set(
                v[:, 0].astype(vp_l.dtype), mode="drop")
            if use_pallas:
                o = paged_decode_attention(
                    q, kp_l.astype(self.dtype), vp_l.astype(self.dtype),
                    page_table, pos,
                    interpret=impl == "pallas_interpret",
                    variant=eff_variant)
            else:
                kd = kp_l[page_table].reshape(
                    B, T, cfg.num_kv_heads, self.head_dim).astype(self.dtype)
                vd = vp_l[page_table].reshape(
                    B, T, cfg.num_kv_heads, self.head_dim).astype(self.dtype)
                o = L.attention(q, kd, vd, q_positions=posc[:, None],
                                kv_positions=kv_pos, causal=True,
                                window=cfg.swa_window, impl="xla")
            x = x + L.attn_out(p["attn"], o)
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            if "moe" in p:
                y, _ = M.moe_ffn(h, p["moe"], cfg.moe)
            else:
                y = L.ffn_apply(p["ffn"], h)
            return x + y, kp_l, vp_l

        lead_k, lead_v = [], []
        for i, p in enumerate(params.get("lead_blocks", [])):
            x, kp_l, vp_l = step_block(p, x, k_pages[i], v_pages[i])
            lead_k.append(kp_l)
            lead_v.append(vp_l)

        def body(x, xs):
            p, kp_l, vp_l = xs
            x, kp_l, vp_l = step_block(p, x, kp_l, vp_l)
            return x, (kp_l, vp_l)

        x, (ks, vs) = lax.scan(
            body, x, (params["blocks"],
                      k_pages[self.n_lead:], v_pages[self.n_lead:]))
        if lead_k:
            ks = jnp.concatenate([jnp.stack(lead_k), ks], axis=0)
            vs = jnp.concatenate([jnp.stack(lead_v), vs], axis=0)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return (x[:, -1] @ head), ks, vs
