"""Token sampling — greedy / temperature / top-k, pure jax."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 0.0,
           top_k: int = 0, vocab_size: int = 0) -> jax.Array:
    """logits: (B, Vpad) -> token ids (B,) int32.

    temperature == 0 -> greedy.  ``vocab_size`` masks padded vocab tail.
    """
    logits = logits.astype(jnp.float32)
    if vocab_size and vocab_size < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
