"""InferenceEngine — continuous batching over a paged KV cache.

This is the pure-JAX stand-in for a vLLM instance (DESIGN.md §2), rebuilt
around slot-based continuous batching (the Processor's "adaptive
batching, KV-cache sharing and migration"):

* a persistent engine loop owns a fixed-capacity decode batch; requests
  are *submitted* into it (``submit()`` returns a handle, ``generate()``
  is submit-then-wait) and are admitted mid-decode — prefill for new
  arrivals is interleaved between decode steps, so a request never waits
  for the running batch to drain;
* variable-length prompts coexist in one batch via per-row lengths and
  attention masking — there is no group-by-prompt-length step and no
  dense cache tiling;
* for full-attention transformers the ONLY KV store is the refcounted
  DEVICE-RESIDENT ``PagedKVCache``: prefill scatters KV rows into pages
  on device, and each decode step runs ``paged_decode_step`` straight
  over the pool — the new token's KV is scattered at (page, offset)
  computed from the per-slot page table inside the jitted step, and
  attention reads the non-contiguous pages in place (paged Pallas
  kernel, or an on-device gather under the XLA impl).  Per-step
  host<->device traffic is O(batch) ints (tokens, page tables, sampled
  ids), not O(batch x seq_len) KV bytes; batch-composition changes are
  free.  The dense-view reference path (gather-to-view + decode_step +
  KV tap sync) remains behind ``paged_decode=False`` for A/B and for
  models without the paged hook.  Prompt prefixes found in the
  ``RadixPrefixTree`` are served by aliasing the donor's pages
  (copy-on-write guards partial pages) and chunk-prefilling only the
  unseen suffix;
* recurrent / ring-buffer families (ssm, hybrid, audio, SWA) have no
  token-paged KV; the same scheduler batches their per-sequence state as
  dense rows (split/stacked via ``cache_batch_axes``);
* exact-duplicate (prompt, decode-params) requests are coalesced against
  the in-flight batch (per-request sampling streams are deterministic,
  so duplicates are provably identical at any temperature);
* outputs are bitwise-identical at temperature 0 regardless of admission
  timing: rows are computed independently and masked padding contributes
  exact zeros.

All numerics run on CPU with tiny smoke configs in tests; the same code
lowers under pjit for the dry-run meshes.
"""
from __future__ import annotations

import functools
import threading
import time
import zlib
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.debugsync import named_condition, named_lock
from repro.engine.kvcache import PagedKVCache
from repro.engine.models import build_model
from repro.engine.prefix_tree import RadixPrefixTree
from repro.engine.sampling import sample


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def _batched_sample(logits, keys, temps, *, vocab_size: int):
    """Sample every active row in ONE device call.

    logits: (B, Vpad); keys: (B, 2) per-slot PRNG keys (ignored for
    greedy rows); temps: (B,) float32.  Row-for-row bitwise identical to
    the per-slot ``sample()`` loop it replaces: argmax is per-row, and a
    vmapped split/categorical over a row's key draws the same bits as
    the single-row call (threefry bits depend on flat size only) — so
    per-slot RNG streams are preserved exactly.  Returns (tokens (B,)
    int32, advanced keys (B, 2))."""
    lg = logits.astype(jnp.float32)
    if vocab_size and vocab_size < lg.shape[-1]:
        mask = jnp.arange(lg.shape[-1], dtype=jnp.int32) < vocab_size
        lg = jnp.where(mask, lg, -1e30)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    stoch = temps > 0.0
    pairs = jax.vmap(jax.random.split)(keys)             # (B, 2, 2)
    new_keys, subs = pairs[:, 0], pairs[:, 1]
    safe_t = jnp.where(stoch, temps, 1.0)[:, None]
    drawn = jax.vmap(jax.random.categorical)(subs, lg / safe_t)
    tokens = jnp.where(stoch, drawn.astype(jnp.int32), greedy)
    return tokens, jnp.where(stoch[:, None], new_keys, keys)


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_tokens_saved: int = 0        # tokens served from shared pages
    decode_tokens: int = 0
    batches: int = 0                     # generate() calls
    coalesced_requests: int = 0
    model_loads: int = 0
    load_seconds: float = 0.0
    prefix_hits: int = 0
    admission_waves: int = 0             # scheduler passes that admitted >=1
    priority_jumps: int = 0              # admissions that bypassed FIFO order
    peak_batch: int = 0                  # max concurrent decode slots
    pages_shared: int = 0                # mirrored from PagedKVCache
    tokens_reused: int = 0               # mirrored from PagedKVCache
    pages_migrated_in: int = 0           # pages imported from a peer engine
    pages_migrated_out: int = 0          # pages exported to a peer engine
    migrate_seconds: float = 0.0         # modeled link-transfer time (import side)
    h2d_bytes: int = 0                   # host->device traffic (KV + step inputs)
    d2h_bytes: int = 0                   # device->host traffic (KV + sampled ids)
    view_rebuilds: int = 0               # dense decode-view materializations

    def as_dict(self) -> Dict[str, float]:
        return dict(self.__dict__)


class EngineError(RuntimeError):
    pass


class RequestHandle:
    """Completion handle for one submitted request.

    ``add_done_callback`` is the per-request pipelining hook: the real
    Processor publishes each query's result (and wakes its downstream
    tool tasks) the moment that request retires, instead of waiting for
    the slowest request of the macro-batch.
    """

    def __init__(self, rid: int):
        self.rid = rid
        self._event = threading.Event()
        self._result: Optional[List[int]] = None
        self._error: Optional[BaseException] = None
        self._cb_lock = named_lock("RequestHandle._cb_lock")
        self._callbacks: List[Any] = []       # guarded-by: self._cb_lock

    def add_done_callback(self, fn) -> None:
        """Call ``fn(handle)`` when the request completes (or failed).

        Runs on the engine loop thread (or inline if already done) —
        callbacks must be quick and must not block on engine work.
        """
        with self._cb_lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _fire_callbacks(self) -> None:
        with self._cb_lock:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            # callbacks run inside the engine loop's fatal-error scope;
            # one misbehaving observer must not fail every in-flight
            # request (or kill the loop thread during _fail_all)
            try:
                fn(self)
            except Exception:
                pass

    def _fulfill(self, tokens: List[int]) -> None:
        self._result = tokens
        self._event.set()
        self._fire_callbacks()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._event.set()
        self._fire_callbacks()

    def done(self) -> bool:
        return self._event.is_set()

    def exception(self) -> Optional[BaseException]:
        """The failure, if the request failed (None while pending/ok)."""
        return self._error

    def result(self, timeout: float = 600.0) -> List[int]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not finished "
                               f"after {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


@dataclass
class _Request:
    rid: int
    prompt: tuple
    extra: Dict[str, Any]
    max_new: int
    temperature: float
    handle: RequestHandle
    priority: int = 0                    # SLO lane (DESIGN.md §10.3)


@dataclass
class _Slot:
    req: _Request
    seq_id: Optional[int] = None         # paged path
    row: Any = None                      # dense path: B=1 cache pytree
    length: int = 0                      # tokens whose KV is stored
    last_token: int = -1
    remaining: int = 0                   # samples still to produce
    generated: List[int] = field(default_factory=list)
    followers: List[RequestHandle] = field(default_factory=list)
    rng: Optional[jax.Array] = None
    view_ix: int = -1                    # row index in the current view


class _Defer(Exception):
    """Admission must wait for pages freed by in-flight retirements."""


class InferenceEngine:
    """One engine instance == one Halo GPU-worker's resident model."""

    MIN_SHARED_PREFIX = 4        # tokens; below this, page aliasing not worth it
    _T_QUANTUM = 32              # decode-view time bucket (bounds recompiles)
    _PF_QUANTUM = 16             # chunk-prefill suffix bucket (share points
                                 # are timing-dependent under streaming)

    def __init__(self, cfg: ModelConfig, seed: int = 0, max_batch: int = 8,
                 enable_prefix_sharing: bool = True, page_size: int = 8,
                 num_pages: Optional[int] = None, max_seq_len: int = 512,
                 max_warm_sequences: int = 32, paged_decode: bool = True,
                 admission_window: float = 0.0,
                 kernel_variant: Optional[str] = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.seed = seed
        self.max_batch = max_batch
        self.enable_prefix_sharing = enable_prefix_sharing
        self.page_size = page_size
        self.max_seq_len = max_seq_len
        self.max_warm_sequences = max_warm_sequences
        # decode straight from the device-resident pages (paged_decode_step)
        # vs. the dense-view reference path (gather + decode_step); the
        # dense path stays for A/B and for models without the paged hook
        self.paged_decode = paged_decode
        # paged-kernel variant override (None = the autotune table in
        # kernels/paged_decode_attention; the A/B harness pins it)
        self.kernel_variant = kernel_variant
        # grace window (seconds): a fresh batch waits this long after the
        # LAST submission before admitting, so near-simultaneous
        # (pipelined, staggered) arrivals form ONE decode batch shape
        # instead of fragmenting into per-arrival recompiles.  Applied
        # only while the engine is idle — a running batch is never stalled.
        self.admission_window = admission_window
        self.params = None   # guarded-by: self._cv | engine-loop ;; memspace: device
        self.stats = EngineStats()
        self.warm_prefixes = RadixPrefixTree()  # guarded-by: self._cv | engine-loop
        self._paged_layout = self.model.paged_kv_layout()
        self._use_paged = bool(self._paged_layout) and paged_decode \
            and hasattr(self.model, "paged_decode_step")
        self.num_pages = num_pages or max(
            64, 2 * max_batch * -(-max_seq_len // page_size))
        self.kv: Optional[PagedKVCache] = None   # guarded-by: self._cv | engine-loop
        # jitted steps (cached per input/cache shape signature)
        # jit-ok: cache/toks shapes ARE the bucketing keys (view pad, _round_t)
        self._decode_jit = jax.jit(
            lambda p, tok, cache: self.model.decode_step(p, tok, cache))
        # jit-ok: cold prefill; toks already padded to _PF_QUANTUM buckets
        self._prefill_jit = jax.jit(
            lambda p, toks: self.model.prefill(p, toks))
        if self._paged_layout:
            # jit-ok: suffix chunks arrive _round_t-bucketed; n is traced
            self._chunk_prefill_jit = jax.jit(
                lambda p, toks, cache, n: self.model.prefill_with_cache(
                    p, toks, cache, valid_len=n))
        if self._use_paged:
            # the pool arrays flow through the step; donating them lets
            # XLA scatter in place on device backends (CPU ignores it)
            donate = (2, 3) if jax.default_backend() != "cpu" else ()
            self._paged_step_jit = jax.jit(
                lambda p, tok, kp, vp, pt, ln: self.model.paged_decode_step(
                    p, tok, kp, vp, pt, ln, variant=self.kernel_variant),
                donate_argnums=donate)
        # scheduler state — owned by the loop thread ("engine-loop"),
        # shared with submitters/importers under _cv (DESIGN.md §11)
        self._cv = named_condition("InferenceEngine._cv")
        self._pending: "deque[_Request]" = deque()   # guarded-by: self._cv | engine-loop
        self._active: List[_Slot] = []               # guarded-by: self._cv | engine-loop
        self._warm: "OrderedDict[int, tuple]" = OrderedDict()  # guarded-by: self._cv | engine-loop
        self._view = None    # guarded-by: self._cv | engine-loop ;; memspace: device
        self._view_pad = 0               # guarded-by: self._cv | engine-loop
        self._dirty = True               # guarded-by: self._cv | engine-loop
        self._loop_thread: Optional[threading.Thread] = None
        self._stepping = False           # guarded-by: self._cv
        self._shutdown = False           # guarded-by: self._cv
        self._rid = 0                    # guarded-by: self._cv
        self._zero_key = jax.random.PRNGKey(0)
        self._last_submit = 0.0          # guarded-by: self._cv

    # ---------------------------------------------------------------- weights
    def load(self) -> float:
        """Materialize params (the T_model event). Returns seconds."""
        if self.params is not None:
            return 0.0
        t0 = time.perf_counter()
        self.params = self.model.init(jax.random.PRNGKey(self.seed))
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        self.stats.model_loads += 1
        self.stats.load_seconds += dt
        return dt

    def unload(self) -> None:
        """Drain in-flight work, then drop params, pages and warm prefixes."""
        with self._cv:
            self._wait_idle_locked(time.monotonic() + 600.0)
            self.params = None
            self.kv = None
            self._warm.clear()
            self.warm_prefixes = RadixPrefixTree()
            self._view = None
            self._dirty = True

    @property
    def loaded(self) -> bool:
        return self.params is not None

    def param_bytes(self) -> int:
        return self.cfg.param_count() * 2          # bf16

    # ----------------------------------------------------------- submission
    def submit(self, prompt: Sequence[int], *, max_new_tokens: int = 16,
               temperature: float = 0.0,
               extra: Optional[Dict[str, Any]] = None,
               priority: int = 0) -> RequestHandle:
        """Enqueue one request into the persistent engine loop.

        Returns immediately; the request joins the running decode batch at
        the next admission pass (mid-decode if a batch is in flight).
        ``priority`` is the SLO lane (DESIGN.md §10.3): each admission
        pass picks the highest-priority waiting request (FIFO within a
        lane), so an interactive request preempts batch-lane admission —
        including under KV-pool pressure, where a deferred interactive
        request holds the pass rather than letting batch work slip past
        it.  All-equal priorities reduce exactly to FIFO.
        """
        if not self._paged_layout \
                and len(prompt) + max_new_tokens > self.max_seq_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds engine max_seq_len ({self.max_seq_len}); dense-row "
                f"caches would wrap and corrupt state")
        with self._cv:
            if self._shutdown:
                raise EngineError("engine is shut down")
            self._rid += 1
            req = _Request(self._rid, tuple(int(t) for t in prompt),
                           dict(extra or {}), max_new_tokens, temperature,
                           RequestHandle(self._rid), priority=int(priority))
            self._pending.append(req)
            self._last_submit = time.monotonic()
            self._ensure_loop()
            self._cv.notify_all()
        return req.handle

    def generate(self, prompts: Sequence[Sequence[int]], *,
                 max_new_tokens: int = 16, temperature: float = 0.0,
                 extras: Optional[List[Dict[str, Any]]] = None,
                 ) -> List[List[int]]:
        """Generate continuations for a batch of token prompts.

        Submit-then-wait over the continuous-batching loop: the prompts
        join whatever is already in flight.  Deterministic for
        temperature=0.  Identical prompts are coalesced.  Returns one
        generated-token list per prompt (same order).
        """
        extras = extras or [{} for _ in prompts]
        handles = [self.submit(p, max_new_tokens=max_new_tokens,
                               temperature=temperature, extra=e)
                   for p, e in zip(prompts, extras)]
        self.stats.batches += 1
        return [h.result() for h in handles]

    # requires: self._cv
    def _wait_idle_locked(self, deadline: float) -> None:
        """Wait (holding _cv) until the loop is quiescent: nothing queued,
        nothing in flight, and the loop thread is not inside _step().
        While the caller keeps holding _cv afterwards, the loop cannot
        start a new step, so engine state is safe to mutate."""
        while self._pending or self._active or self._stepping:
            if not self._cv.wait(timeout=min(1.0,
                                             deadline - time.monotonic())):
                if time.monotonic() >= deadline:
                    raise TimeoutError("engine drain timed out")

    def drain(self, timeout: float = 600.0) -> None:
        """Block until no request is pending or in flight."""
        with self._cv:
            self._wait_idle_locked(time.monotonic() + timeout)

    def reset_peak_batch(self) -> None:
        """Reset the peak-concurrency watermark to the current batch size.

        ``peak_batch`` is a high-watermark gauge; per-run reporting over
        persistent hosts resets it at run start so a later run does not
        re-report an earlier run's peak.
        """
        with self._cv:
            self.stats.peak_batch = len(self._active)

    # ------------------------------------------------------- kv migration
    # requires: self._cv
    def _wait_step_gap_locked(self, deadline: float) -> None:
        """Wait (holding _cv) until the loop thread is between steps.
        While the caller keeps holding _cv, the loop cannot enter the
        next step, so pages / warm set / radix tree are safe to touch
        even with work in flight."""
        while self._stepping:
            if not self._cv.wait(timeout=min(1.0,
                                             deadline - time.monotonic())):
                if time.monotonic() >= deadline:
                    raise TimeoutError("engine never paused between steps")

    # requires: self._cv | engine-loop
    def _find_warm_donor(self, tokens: Sequence[int],
                         cap: Optional[int] = None):
        """Deepest valid warm donor covering a prefix of ``tokens``:
        ``(seq_id, depth)``, or ``(None, 0)``.  ``cap`` bounds the usable
        depth (admission caps at S-1 so one fresh token remains to
        decode from).  Caller must either BE the loop thread or hold
        ``_cv`` in a step gap — donors can be evicted mid-step."""
        kv = self.kv
        if kv is None or not self.enable_prefix_sharing:
            return None, 0
        _, cands = self.warm_prefixes.match_all(tokens)
        for depth, payload in cands:                     # deepest first
            d = depth if cap is None else min(depth, cap)
            if (d >= self.MIN_SHARED_PREFIX and isinstance(payload, int)
                    and payload in kv.sequences
                    and kv.sequences[payload].length >= d):
                return payload, d
        return None, 0

    def probe_prefix(self, prompt: Sequence[int], timeout: float = 60.0
                     ) -> int:
        """Longest warm-donor prefix of ``prompt`` resident here (tokens);
        0 when nothing useful is cached.  Thread-safe (runs in a step
        gap, like export) — lets a migrator decide migrate-vs-recompute
        before paying the export."""
        prompt = tuple(int(t) for t in prompt)
        deadline = time.monotonic() + timeout
        with self._cv:
            self._wait_step_gap_locked(deadline)
            return self._find_warm_donor(prompt)[1]

    def export_prefix(self, prompt: Sequence[int], timeout: float = 60.0):
        """Export the warm KV prefix matching ``prompt``.

        Returns ``(tokens, k, v)`` — the matched prompt prefix plus
        contiguous per-layer KV copies — or None when no warm donor
        covers at least MIN_SHARED_PREFIX tokens.  Thread-safe: runs in
        a gap between engine steps so an eviction or copy-on-write
        cannot mutate the donor's pages mid-copy.
        """
        prompt = tuple(int(t) for t in prompt)
        deadline = time.monotonic() + timeout
        with self._cv:
            self._wait_step_gap_locked(deadline)
            donor, depth = self._find_warm_donor(prompt)
            if donor is None:
                return None
            kv = self.kv
            # pages_migrated_out is NOT counted here: the caller credits
            # it only once the destination confirms the import, so the
            # out/in counters track real transfers, not attempts
            k, v = kv.export_sequence(donor, depth)
            # the migration boundary is the ONE place the device pool
            # stages through the host (priced by the caller as before)
            self.stats.d2h_bytes += k.nbytes + v.nbytes
            return prompt[:depth], k, v

    def import_prefix(self, tokens: Sequence[int], k, v,
                      migrate_seconds: float = 0.0,
                      timeout: float = 60.0) -> int:
        """Adopt a migrated KV prefix as a warm donor: write the pages,
        register the sequence in the warm set and stamp the radix tree so
        the next admission of a matching prompt aliases it.

        Best-effort: returns the number of pages imported, or 0 when the
        prefix is already resident or the pool has no headroom beyond the
        active batch's decode reservation (migration must never destabil-
        ize in-flight work).  ``migrate_seconds`` is the modeled link-
        transfer time the caller priced the copy at.
        """
        tokens = tuple(int(t) for t in tokens)
        if not self._paged_layout or not self.enable_prefix_sharing \
                or len(tokens) < self.MIN_SHARED_PREFIX:
            return 0                                 # donor would be unusable
        deadline = time.monotonic() + timeout
        with self._cv:
            self._wait_step_gap_locked(deadline)
            kv = self._ensure_kv()
            if self._find_warm_donor(tokens)[1] >= len(tokens):
                return 0                             # already resident
            need = -(-len(tokens) // self.page_size)
            # feasibility BEFORE evicting anything: a page is reclaimable
            # only if every reference to it comes from warm sequences —
            # an infeasible import must not wipe the destination's warm
            # locality just to fail anyway
            warm_refs: Dict[int, int] = {}
            for seq_id in self._warm:
                for p in kv.sequences[seq_id].page_ids:
                    warm_refs[p] = warm_refs.get(p, 0) + 1
            reclaimable = sum(1 for p, n in warm_refs.items()
                              if n == kv.refcount[p])
            headroom = len(kv.free_pages) - self._reserved_pages()
            if headroom + reclaimable < need:
                return 0
            while headroom < need and self._warm:    # evict LRU warm only
                # prefer victims whose eviction actually frees pages;
                # a warm sequence fully aliased by in-flight work frees
                # nothing and would be destroyed for zero gain (fall
                # back to any victim to unlock warm-warm aliased pages)
                victim = next(
                    (s for s in self._warm
                     if any(kv.refcount[p] == 1
                            for p in kv.sequences[s].page_ids)),
                    None) or next(iter(self._warm))
                self._warm.pop(victim)
                kv.free_sequence(victim)
                headroom = len(kv.free_pages) - self._reserved_pages()
            if headroom < need:
                return 0
            seq = kv.import_sequence(k, v)
            self.stats.h2d_bytes += k.nbytes + v.nbytes   # staging upload
            self._warm[seq] = tokens
            self._warm.move_to_end(seq)
            while len(self._warm) > self.max_warm_sequences:
                victim, _ = self._warm.popitem(last=False)
                kv.free_sequence(victim)
            self.warm_prefixes.insert(tokens, payload=seq, stamp_path=True)
            self._maybe_prune_tree()
            pages = len(kv.sequences[seq].page_ids)
            self.stats.pages_migrated_in += pages
            self.stats.migrate_seconds += migrate_seconds
            return pages

    def release_warm(self, timeout: float = 600.0) -> None:
        """Free every warm (retained-for-prefix-reuse) sequence's pages.

        Waits for the engine to go idle first — the warm set and page
        refcounts belong to the loop thread while work is in flight.
        """
        with self._cv:
            self._wait_idle_locked(time.monotonic() + timeout)
            for seq_id in list(self._warm):
                self.kv.free_sequence(seq_id)
            self._warm.clear()

    def shutdown(self) -> None:
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        if self._loop_thread is not None:
            self._loop_thread.join(timeout=10)

    # ------------------------------------------------------------- the loop
    def _ensure_loop(self) -> None:
        if self._loop_thread is None or not self._loop_thread.is_alive():
            self._loop_thread = threading.Thread(
                target=self._run_loop, daemon=True,
                name=f"engine-{self.cfg.name}")
            self._loop_thread.start()

    # runs-on: engine-loop
    def _run_loop(self) -> None:
        while True:
            with self._cv:
                while not self._shutdown and not self._pending \
                        and not self._active:
                    self._cv.wait()
                if self._shutdown:
                    return
                self._stepping = True
            try:
                self._step()
            except BaseException as e:                  # engine-fatal
                self._fail_all(e)
            finally:
                with self._cv:
                    self._stepping = False
                    self._cv.notify_all()

    def _fail_all(self, err: BaseException) -> None:
        with self._cv:
            victims = list(self._pending)
            self._pending.clear()
            slots, self._active = self._active, []
        for req in victims:
            req.handle._fail(err)
        for s in slots:
            s.req.handle._fail(err)
            for f in s.followers:
                f._fail(err)
            # return the slot's pages: a failed batch must not leak them
            if self.kv is not None and s.seq_id in self.kv.sequences:
                try:
                    self.kv.free_sequence(s.seq_id)
                except Exception:
                    pass                        # pool corrupt > pool leaked
        self._dirty = True
        self._view = None

    def _step(self) -> None:
        """One scheduler iteration: admit, then one decode step."""
        self._grace_window()
        self._admit()
        if self._active:
            self._decode_once()

    def _grace_window(self) -> None:
        """Hold a FRESH batch's admission until ``admission_window``
        seconds have passed since the last submission (capped at 10
        windows), so a burst of staggered arrivals lands as one
        admission wave / one batch shape.  Running batches are never
        delayed — mid-decode arrivals batch naturally between steps."""
        w = self.admission_window
        if w <= 0 or self._active:
            return
        cap = time.monotonic() + 10 * w
        with self._cv:
            while not self._shutdown and self._pending:
                now = time.monotonic()
                wait = self._last_submit + w - now
                if wait <= 0 or now >= cap:
                    break
                self._cv.wait(timeout=min(wait, cap - now))

    # ------------------------------------------------------------- admission
    def _admit(self) -> None:
        admitted = 0
        while len(self._active) < self.max_batch:
            with self._cv:
                if not self._pending:
                    break
                # peek the highest-priority waiting request (max() keeps
                # the FIRST maximum, so equal priorities — the common
                # all-zero case — are exact FIFO); the request stays
                # visible to drain() until it has a slot (only the loop
                # thread ever removes it)
                req = max(self._pending, key=lambda r: r.priority)
                jumped = req is not self._pending[0]
            if self._coalesce(req):
                self._remove_pending(req)
                continue
            try:
                slot = self._admit_one(req)
            except _Defer:
                # left in the queue: under KV-pool pressure the deferred
                # request blocks the whole pass, so lower-priority work
                # can never be admitted around a waiting interactive
                # request (it preempts batch, never vice versa)
                break
            except BaseException as e:                  # per-request failure
                self._remove_pending(req)
                req.handle._fail(e)
                continue
            # attach still-queued exact duplicates NOW: a leader that
            # retires within this admission pass (small max_new) would
            # otherwise leave _active before its duplicates reach
            # _coalesce, and both would prefill
            slot.followers.extend(self._claim_pending_duplicates(req))
            if slot.remaining > 0:
                self._active.append(slot)
                admitted += 1
            else:
                self._retire(slot)
            self._remove_pending(req)
            if jumped:
                self.stats.priority_jumps += 1
        if admitted:
            self.stats.admission_waves += 1
            self.stats.peak_batch = max(self.stats.peak_batch,
                                        len(self._active))
            self._dirty = True

    @staticmethod
    def _duplicates(a: _Request, b: _Request) -> bool:
        return (not a.extra and not b.extra and a.prompt == b.prompt
                and a.max_new == b.max_new
                and a.temperature == b.temperature)

    def _coalesce(self, req: _Request) -> bool:
        """Attach an exact duplicate of an in-flight request as follower.

        Per-request sampling streams are a pure function of (engine seed,
        prompt, max_new) — see _request_rng — so two requests with equal
        (prompt, max_new, temperature) provably decode the same tokens at
        ANY temperature; the leader's full output is the follower's.
        """
        if req.extra:
            return False
        for s in self._active:
            if self._duplicates(s.req, req):
                s.followers.append(req.handle)
                self.stats.coalesced_requests += 1
                return True
        return False

    def _claim_pending_duplicates(self, req: _Request) -> List[RequestHandle]:
        """Pop every exact duplicate of ``req`` still waiting in _pending
        and return their handles — the admission-time counterpart of
        _coalesce, covering duplicates submitted in the same wave."""
        if req.extra:
            return []
        out: List[RequestHandle] = []
        with self._cv:
            kept: "deque[_Request]" = deque()
            for r in self._pending:
                if r is not req and self._duplicates(r, req):
                    out.append(r.handle)
                else:
                    kept.append(r)
            self._pending = kept
        self.stats.coalesced_requests += len(out)
        return out

    def _request_rng(self, req: _Request) -> jax.Array:
        """Per-request stream, stable under plan/arrival reordering."""
        h = zlib.crc32(np.asarray(req.prompt, np.int64).tobytes())
        h = zlib.crc32(np.asarray([req.max_new], np.int64).tobytes(), h)
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), h)

    # requires: self._cv | engine-loop
    def _ensure_kv(self) -> PagedKVCache:
        if self.kv is None:
            layers, kv_heads, head_dim = self._paged_layout
            self.kv = PagedKVCache(layers, self.num_pages, self.page_size,
                                   kv_heads, head_dim)
        return self.kv

    def _remove_pending(self, req: _Request) -> None:
        with self._cv:
            try:
                self._pending.remove(req)
            except ValueError:       # already claimed as a duplicate
                pass

    # requires: self._cv | engine-loop
    def _reserved_pages(self) -> int:
        """Pages the in-flight batch may still allocate: each active slot
        appends one token's KV per remaining step (+1 for page-boundary
        slack).  Admission must leave this headroom free or a decode-time
        ``append_token`` could exhaust the pool and fail the whole batch."""
        ps = self.page_size
        return sum(-(-s.remaining // ps) + 1 for s in self._active)

    # requires: self._cv | engine-loop
    def _ensure_pages(self, needed: int, protect: Optional[int] = None) -> None:
        """Evict warm sequences (LRU, never ``protect``) until ``needed``
        pages are free beyond the active batch's decode reservation;
        defer admission if in-flight work will free more."""
        kv = self.kv
        if needed > kv.num_pages:
            # can NEVER fit — not even with every warm sequence evicted
            # and the active batch fully drained.  Deferring would
            # livelock behind in-flight work until the caller's 600s
            # result() timeout; fail the request now with a diagnosis.
            raise MemoryError(
                f"request needs {needed} KV pages but the pool holds only "
                f"{kv.num_pages} ({kv.page_size} tokens/page) — it cannot "
                f"be admitted even after evicting all warm sequences; "
                f"raise num_pages/max_seq_len or shrink the prompt / "
                f"max_new_tokens")
        needed += self._reserved_pages()
        while len(kv.free_pages) < needed:
            victim = next((s for s in self._warm if s != protect), None)
            if victim is None:
                if self._active:
                    raise _Defer()
                raise MemoryError(
                    f"KV cache out of pages ({needed} needed, "
                    f"{len(kv.free_pages)} free, no warm sequences left)")
            self._warm.pop(victim)
            kv.free_sequence(victim)

    def _prefill(self, tokens: jax.Array, extra: Dict[str, Any]):
        if self.cfg.family == "audio":
            return self.model.prefill(self.params, tokens, extra["frames"])
        if self.cfg.family == "vlm" and extra.get("patch_embeds") is not None:
            return self.model.prefill(self.params, tokens,
                                      prefix_embeds=extra["patch_embeds"])
        return self._prefill_jit(self.params, tokens)

    def _kv_rows(self, cache, row: int, length: int):
        """One prefill row's KV in the page-store write format — device
        arrays when the model exposes the device hook (no staging), host
        float32 otherwise (the pool uploads them on write)."""
        if hasattr(self.model, "cache_kv_rows_dev"):
            return self.model.cache_kv_rows_dev(cache, row, length)
        k, v = self.model.cache_kv_rows(cache, row)
        self.stats.d2h_bytes += k.nbytes + v.nbytes
        self.stats.h2d_bytes += k.nbytes + v.nbytes
        return k, v

    def _admit_one(self, req: _Request) -> _Slot:
        if self.params is None:
            self.load()
        S = len(req.prompt)
        slot = _Slot(req=req, remaining=req.max_new,
                     rng=self._request_rng(req))
        shareable = (self.enable_prefix_sharing and not req.extra and S > 1)

        if self._paged_layout:
            kv = self._ensure_kv()
            donor = None
            shared = 0
            if shareable:
                # deepest-first fallback; cap at S-1 so one fresh token
                # remains to decode from
                donor, shared = self._find_warm_donor(req.prompt, cap=S - 1)
            fresh_tokens = S - shared + req.max_new
            if req.extra.get("patch_embeds") is not None:
                fresh_tokens += req.extra["patch_embeds"].shape[-2]
            self._ensure_pages(-(-fresh_tokens // self.page_size) + 1,
                               protect=donor)
            if donor is not None:
                logits = self._prefill_shared(slot, donor, shared)
                self.stats.prefix_hits += 1
                self.stats.prefill_tokens += S - shared
                self.stats.prefill_tokens_saved += shared
            elif not req.extra and hasattr(self.model,
                                           "prefill_with_cache"):
                # cold prompts run the SAME bucketed chunk-prefill step
                # as shared ones: whether a prompt finds a warm donor is
                # timing-dependent under streaming arrivals, so giving
                # the cold path its own per-length compiled shape would
                # re-trace run to run
                logits = self._prefill_cold(slot)
                self.stats.prefill_tokens += S
            else:
                tokens = jnp.asarray([req.prompt], jnp.int32)
                logits, cache = self._prefill(tokens, req.extra)
                S_kv = S
                if req.extra.get("patch_embeds") is not None:
                    S_kv += req.extra["patch_embeds"].shape[-2]
                k_row, v_row = self._kv_rows(cache, 0, S_kv)
                slot.seq_id = kv.add_sequence(k_row, v_row)
                self.stats.prefill_tokens += S_kv
            slot.length = kv.sequences[slot.seq_id].length
            if shareable:
                self.warm_prefixes.insert(req.prompt, payload=slot.seq_id,
                                          stamp_path=True)
            self.stats.pages_shared = kv.pages_shared
            self.stats.tokens_reused = kv.tokens_reused
        else:
            tokens = jnp.asarray([req.prompt], jnp.int32)
            logits, cache = self._prefill(tokens, req.extra)
            t_cur = S
            slot.row = self.model.extend_cache(cache,
                                               self.max_seq_len - t_cur)
            slot.length = S
            self.stats.prefill_tokens += S

        if req.max_new > 0:
            self._emit_token(slot, logits[0:1])
        return slot

    def _prefill_cold(self, slot: _Slot):
        """Prefill a donor-less prompt via the bucketed chunk step over
        an empty cache view — one compiled shape per (suffix bucket,
        time bucket) instead of one per prompt length."""
        kv = self.kv
        req = slot.req
        S = len(req.prompt)
        pad = -(-S // self._PF_QUANTUM) * self._PF_QUANTUM
        T1 = self._round_t(pad + req.max_new)
        layers, heads, dh = self._paged_layout
        k_rows = jnp.zeros((1, layers, T1, heads, dh), jnp.float32)
        v_rows = jnp.zeros((1, layers, T1, heads, dh), jnp.float32)
        cache = self.model.paged_cache_view(k_rows, v_rows, [0])
        toks = jnp.asarray([list(req.prompt) + [0] * (pad - S)], jnp.int32)
        logits, cache = self._chunk_prefill_jit(
            self.params, toks, cache, jnp.asarray([S], jnp.int32))
        k_row, v_row = self._kv_rows(cache, 0, S)           # (L, S, H, D)
        slot.seq_id = kv.add_sequence(k_row, v_row)
        return logits

    def _prefill_shared(self, slot: _Slot, donor: int, shared: int):
        """Admit via page aliasing: reuse the donor's first ``shared``
        tokens, chunk-prefill only the unseen suffix, append its KV.
        The reused prefix is gathered from the device pool and the
        suffix KV written back through it entirely on device."""
        kv = self.kv
        req = slot.req
        seq = kv.add_sequence(shared_from=donor, shared_len=shared)
        slot.seq_id = seq
        kp, vp = kv.gather(seq)              # device (L, shared, H, D)
        S = len(req.prompt)
        # pad the suffix to a quantum: the share point depends on which
        # prefixes happen to be warm at admission time, so under
        # streaming arrivals raw suffix shapes are timing-dependent and
        # each one would JIT-compile its own chunk-prefill step
        n_suf = S - shared
        pad = -(-n_suf // self._PF_QUANTUM) * self._PF_QUANTUM
        T1 = self._round_t(shared + pad + req.max_new)
        L, _, H, D = kp.shape
        k_rows = jnp.zeros((1, L, T1, H, D), jnp.float32).at[
            0, :, :shared].set(kp)
        v_rows = jnp.zeros((1, L, T1, H, D), jnp.float32).at[
            0, :, :shared].set(vp)
        cache = self.model.paged_cache_view(k_rows, v_rows, [shared])
        suffix = jnp.asarray(
            [list(req.prompt[shared:]) + [0] * (pad - n_suf)], jnp.int32)
        logits, cache = self._chunk_prefill_jit(
            self.params, suffix, cache, jnp.asarray([n_suf], jnp.int32))
        k_row, v_row = self._kv_rows(cache, 0, S)           # (L, S, H, D)
        kv.extend_sequence(seq, k_row[:, shared:], v_row[:, shared:])
        return logits

    # ---------------------------------------------------------------- decode
    def _round_t(self, n: int) -> int:
        q = self._T_QUANTUM
        return -(-n // q) * q

    @staticmethod
    def _round_b(n: int) -> int:
        b = 1
        while b < n:
            b *= 2
        return b

    def _rebuild_view(self) -> None:
        """Re-materialize the dense decode batch after composition change.

        Paged models gather every active row from its pages (the pages
        stay authoritative); dense-row models restack per-sequence rows.
        Batch is padded to a power of two and time to _T_QUANTUM multiples
        so recompiles stay bounded; padded rows compute garbage that is
        never sampled and never written back anywhere.
        """
        slots = self._active
        b_pad = self._round_b(len(slots))
        self.stats.view_rebuilds += 1
        if self._paged_layout:
            kv = self.kv
            t_view = self._round_t(max(s.length + s.remaining for s in slots))
            layers, heads, dh = self._paged_layout
            k_rows = np.zeros((b_pad, layers, t_view, heads, dh), np.float32)
            v_rows = np.zeros_like(k_rows)
            lengths = [0] * b_pad
            for i, s in enumerate(slots):
                kr, vr = kv.gather(s.seq_id)
                # device pool -> host rows -> padded device view: the
                # O(batch x seq_len) round-trip the paged path deletes
                self.stats.d2h_bytes += kr.nbytes + vr.nbytes
                k_rows[i, :, :s.length] = kr
                v_rows[i, :, :s.length] = vr
                lengths[i] = s.length
            self.stats.h2d_bytes += k_rows.nbytes + v_rows.nbytes
            self._view = self.model.paged_cache_view(k_rows, v_rows, lengths)
        else:
            rows = self._dense_rows() + [None] * (b_pad - len(slots))
            axes = self.model.cache_batch_axes(rows[0])
            dummy = jax.tree.map(jnp.zeros_like, rows[0])
            rows = [dummy if r is None else r for r in rows]
            self._view = {
                key: jnp.concatenate([r[key] for r in rows], axis=ax)
                for key, ax in axes.items()}
        self._view_pad = b_pad
        self._dirty = False

    def _dense_rows(self) -> List[Any]:
        """Per-slot cache rows; slots already in the current view are
        sliced back out of it (they carry the decoded state)."""
        out = []
        for s in self._active:
            if s.row is None:
                s.row = self._slice_row(self._view, s.view_ix)
            out.append(s.row)
            s.row = None                    # ownership moves into the view
        return out

    def _slice_row(self, view, ix: int):
        axes = self.model.cache_batch_axes(view)
        return {k: jax.lax.slice_in_dim(v, ix, ix + 1, axis=axes[k])
                for k, v in view.items()}

    def _decode_once(self) -> None:
        if self._use_paged:
            self._decode_paged()
            return
        if self._dirty:
            self._rebuild_view()
            for i, s in enumerate(self._active):
                s.view_ix = i
        slots = self._active
        b_real = len(slots)
        tokens = np.zeros((self._view_pad,), np.int32)
        tokens[:b_real] = [s.last_token for s in slots]
        prev_lengths = [s.length for s in slots]
        self.stats.h2d_bytes += tokens.nbytes
        logits, self._view = self._decode_jit(
            self.params, jnp.asarray(tokens), self._view)
        if self._paged_layout:
            taps_ix = np.zeros((self._view_pad,), np.int32)
            taps_ix[:b_real] = prev_lengths      # identity slots (no wrap)
            k_taps, v_taps = self.model.decode_kv_taps(self._view, taps_ix)
            # fresh KV taps sync to host, then re-upload into the pool —
            # the per-step D2H round-trip the paged path deletes
            self.stats.d2h_bytes += k_taps.nbytes + v_taps.nbytes
            k_taps, v_taps = k_taps[:, :b_real], v_taps[:, :b_real]
            self.stats.h2d_bytes += k_taps.nbytes + v_taps.nbytes
            self.kv.append_tokens([s.seq_id for s in slots], k_taps, v_taps)
        for s in slots:
            s.length += 1
        self.stats.decode_tokens += b_real
        self._advance(logits)

    def _decode_paged(self) -> None:
        """One decode step straight over the device-resident page pool:
        upload O(batch) metadata (tokens, page tables, lengths), run the
        paged step (in-pool KV scatter + paged attention), download
        O(batch) sampled ids.  No dense view exists, so composition
        changes are free — no ``_rebuild_view``, no KV tap sync."""
        kv = self.kv
        slots = self._active
        b_real = len(slots)
        # page alloc + COW (host metadata): after this every write-target
        # page is private to its row — the fused append+attend kernel's
        # safety contract (the step derives (page, offset) from the
        # uploaded table, so the returned targets aren't re-shipped)
        kv.prepare_appends([s.seq_id for s in slots])
        b_pad = self._round_b(b_real)
        # pad like the dense view's quanta so recompiles stay bounded
        t_cap = self._round_t(max(s.length + s.remaining for s in slots))
        n_pages = -(-t_cap // self.page_size)
        pt = np.zeros((b_pad, n_pages), np.int32)
        lens = np.full((b_pad,), -1, np.int32)
        tokens = np.zeros((b_pad,), np.int32)
        for i, s in enumerate(slots):
            ids = kv.sequences[s.seq_id].page_ids
            pt[i, :len(ids)] = ids
            lens[i] = s.length
            tokens[i] = s.last_token
        self.stats.h2d_bytes += pt.nbytes + lens.nbytes + tokens.nbytes
        logits, new_k, new_v = self._paged_step_jit(
            self.params, jnp.asarray(tokens), kv.k, kv.v,
            jnp.asarray(pt), jnp.asarray(lens))
        kv.adopt_pages(new_k, new_v)
        kv.commit_appends([s.seq_id for s in slots])
        for s in slots:
            s.length += 1
        self.stats.decode_tokens += b_real
        self._advance(logits)

    def _emit_token(self, slot: _Slot, logits) -> None:
        """Sample one token for ``slot`` from (1, Vpad) logits."""
        if slot.req.temperature == 0.0:
            nxt = sample(logits, self._zero_key, temperature=0.0,
                         vocab_size=self.cfg.vocab_size)
        else:
            slot.rng, sub = jax.random.split(slot.rng)
            nxt = sample(logits, sub, temperature=slot.req.temperature,
                         vocab_size=self.cfg.vocab_size)
        tok = int(nxt[0])
        slot.generated.append(tok)
        slot.last_token = tok
        slot.remaining -= 1

    def _advance(self, logits) -> None:
        """Advance every active slot from one decode step's logits.

        The whole (B, Vpad) batch is sampled in a single device call and
        synced once (one O(batch)-ints D2H per step) — no per-slot
        logits slicing or per-sequence ``int()`` syncs."""
        slots = list(self._active)
        b = len(slots)
        b_pad = logits.shape[0]          # sample the PADDED batch so the
        temps = [s.req.temperature for s in slots]   # jit stays keyed on
        temps_pad = np.zeros((b_pad,), np.float32)   # the step's quanta,
        temps_pad[:b] = temps            # not on every live-slot count
        if any(t != 0.0 for t in temps):
            zero = jnp.zeros_like(slots[0].rng)
            keys = jnp.stack([s.rng for s in slots]
                             + [zero] * (b_pad - b))
        else:                            # greedy rows never read their key
            keys = jnp.zeros((b_pad, 2), jnp.uint32)
        toks_dev, new_keys = _batched_sample(
            logits, keys, jnp.asarray(temps_pad),
            vocab_size=self.cfg.vocab_size)
        toks = np.asarray(toks_dev)      # one O(batch)-ints sync per step
        self.stats.d2h_bytes += toks.nbytes
        finished = []
        for i, s in enumerate(slots):
            if temps[i] != 0.0:
                s.rng = new_keys[i]
            tok = int(toks[i])
            s.generated.append(tok)
            s.last_token = tok
            s.remaining -= 1
            if s.remaining == 0:
                finished.append(s)
        for s in finished:
            self._active.remove(s)
            self._retire(s)
        if finished:
            self._dirty = True

    def _retire(self, slot: _Slot) -> None:
        req = slot.req
        if self._paged_layout and slot.seq_id is not None:
            keep = (self.enable_prefix_sharing and not req.extra)
            if keep:
                self._warm[slot.seq_id] = req.prompt
                self._warm.move_to_end(slot.seq_id)
                while len(self._warm) > self.max_warm_sequences:
                    victim, _ = self._warm.popitem(last=False)
                    self.kv.free_sequence(victim)
                self._maybe_prune_tree()
            else:
                self.kv.free_sequence(slot.seq_id)
        out = list(slot.generated)
        req.handle._fulfill(out)
        for f in slot.followers:
            f._fulfill(list(out))

    # requires: self._cv | engine-loop
    def _maybe_prune_tree(self) -> None:
        """Rebuild the radix tree from live donors once stale entries
        dominate — evicted sequences leave nodes and stamped payloads
        behind, and a persistent-host engine would otherwise grow the
        tree with every prompt it ever served."""
        if self.warm_prefixes.num_sequences <= 8 * self.max_warm_sequences:
            return
        tree = RadixPrefixTree()
        for seq_id, prompt in self._warm.items():
            tree.insert(prompt, payload=seq_id, stamp_path=True)
        for s in self._active:
            if s.seq_id is not None and not s.req.extra:
                tree.insert(s.req.prompt, payload=s.seq_id, stamp_path=True)
        self.warm_prefixes = tree
