"""InferenceEngine — the per-worker LLM serving engine Halo schedules.

This is the pure-JAX stand-in for a vLLM instance (DESIGN.md §2):

* continuous batching: requests are grouped by prompt length, prefilled
  as a padded batch, and decoded in lock-step slots;
* prefix sharing: when a whole group shares a prompt prefix (the normal
  case for Halo's consolidated template batches), the prefix is
  prefilled ONCE (batch 1) and its cache is tiled across the group —
  the compute- and memory-level realization of KV-cache sharing
  (the Pallas shared_prefix_attention kernel is the TPU analogue at the
  attention level; this path is its engine-level counterpart);
* exact-duplicate memoization: identical (prompt, decode-params) calls
  inside one batch run once (request coalescing at the engine edge);
* stateful context: resident params (model switch cost) + a radix tree
  of warm prefixes (Halo's ``u_w`` signature).

All numerics run on CPU with tiny smoke configs in tests; the same code
lowers under pjit for the dry-run meshes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine.models import build_model
from repro.engine.prefix_tree import RadixPrefixTree, batch_shared_prefix
from repro.engine.sampling import sample


@dataclass
class EngineStats:
    prefill_tokens: int = 0
    prefill_tokens_saved: int = 0        # via shared-prefix tiling
    decode_tokens: int = 0
    batches: int = 0
    coalesced_requests: int = 0
    model_loads: int = 0
    load_seconds: float = 0.0
    prefix_hits: int = 0

    def as_dict(self) -> Dict[str, float]:
        return dict(self.__dict__)


class InferenceEngine:
    """One engine instance == one Halo GPU-worker's resident model."""

    MIN_SHARED_PREFIX = 4                # tokens; below this, tiling not worth it

    def __init__(self, cfg: ModelConfig, seed: int = 0, max_batch: int = 8,
                 enable_prefix_sharing: bool = True):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.seed = seed
        self.max_batch = max_batch
        self.enable_prefix_sharing = enable_prefix_sharing
        self.params = None               # lazy: loading == model-switch cost
        self.stats = EngineStats()
        self.warm_prefixes = RadixPrefixTree()
        # jitted steps (cached per input/cache shape signature)
        self._decode_jit = jax.jit(
            lambda p, tok, cache: self.model.decode_step(p, tok, cache))
        self._prefill_jit = jax.jit(
            lambda p, toks: self.model.prefill(p, toks))

    # ---------------------------------------------------------------- weights
    def load(self) -> float:
        """Materialize params (the T_model event). Returns seconds."""
        if self.params is not None:
            return 0.0
        t0 = time.perf_counter()
        self.params = self.model.init(jax.random.PRNGKey(self.seed))
        jax.block_until_ready(jax.tree.leaves(self.params)[0])
        dt = time.perf_counter() - t0
        self.stats.model_loads += 1
        self.stats.load_seconds += dt
        return dt

    def unload(self) -> None:
        self.params = None
        self.warm_prefixes = RadixPrefixTree()

    @property
    def loaded(self) -> bool:
        return self.params is not None

    def param_bytes(self) -> int:
        return self.cfg.param_count() * 2          # bf16

    # ---------------------------------------------------------------- helpers
    def _tile_cache(self, cache, n: int):
        axes = self.model.cache_batch_axes(cache)
        return {k: jnp.repeat(v, n, axis=axes[k]) for k, v in cache.items()}

    def _prefill(self, tokens: jax.Array, extra: Dict[str, Any]):
        if self.cfg.family == "audio":
            return self.model.prefill(self.params, tokens, extra["frames"])
        if self.cfg.family == "vlm" and extra.get("patch_embeds") is not None:
            return self.model.prefill(self.params, tokens,
                                      prefix_embeds=extra["patch_embeds"])
        return self._prefill_jit(self.params, tokens)

    def _decode(self, token: jax.Array, cache):
        return self._decode_jit(self.params, token, cache)

    # ---------------------------------------------------------------- generate
    def generate(self, prompts: Sequence[Sequence[int]], *,
                 max_new_tokens: int = 16, temperature: float = 0.0,
                 extras: Optional[List[Dict[str, Any]]] = None,
                 ) -> List[List[int]]:
        """Generate continuations for a batch of token prompts.

        Deterministic for temperature=0.  Identical prompts are coalesced.
        Returns one generated-token list per prompt (same order).
        """
        if self.params is None:
            self.load()
        extras = extras or [{} for _ in prompts]

        # ---- engine-edge coalescing of exact duplicates ------------------
        uniq: Dict[Tuple[int, ...], int] = {}
        order: List[int] = []
        uniq_prompts: List[Sequence[int]] = []
        uniq_extras: List[Dict[str, Any]] = []
        for p, e in zip(prompts, extras):
            key = tuple(p)
            if key in uniq and not e:
                self.stats.coalesced_requests += 1
            else:
                uniq[key] = len(uniq_prompts)
                uniq_prompts.append(p)
                uniq_extras.append(e)
            order.append(uniq[key])

        # ---- group by prompt length (padding-free batching) --------------
        groups: Dict[int, List[int]] = {}
        for i, p in enumerate(uniq_prompts):
            groups.setdefault(len(p), []).append(i)

        results: List[Optional[List[int]]] = [None] * len(uniq_prompts)
        for idxs in groups.values():
            for j0 in range(0, len(idxs), self.max_batch):
                chunk = idxs[j0:j0 + self.max_batch]
                outs = self._generate_group(
                    [uniq_prompts[i] for i in chunk],
                    [uniq_extras[i] for i in chunk],
                    max_new_tokens, temperature)
                for i, o in zip(chunk, outs):
                    results[i] = o
        self.stats.batches += 1
        return [list(results[j]) for j in order]

    # ---------------------------------------------------------------- group
    def _generate_group(self, prompts, extras, max_new, temperature):
        B, S = len(prompts), len(prompts[0])
        tokens = jnp.asarray(prompts, jnp.int32)
        shared = batch_shared_prefix(prompts) if (
            self.enable_prefix_sharing and B > 1 and not any(extras)) else []
        # recurrent archs share state snapshots only for EXACT prefixes,
        # which is what batch_shared_prefix computes — always valid; but
        # only profitable beyond a minimum length.
        P = len(shared)
        use_shared = P >= self.MIN_SHARED_PREFIX and P < S

        if use_shared:
            # prefill shared prefix ONCE, tile the cache across the group
            logits1, cache = self._prefill(tokens[:1, :P], {})
            cache = self.model.extend_cache(cache, (S - P) + max_new)
            cache = self._tile_cache(cache, B)
            self.stats.prefill_tokens += P
            self.stats.prefill_tokens_saved += P * (B - 1)
            self.warm_prefixes.insert(shared)
            # teacher-force per-request suffixes (uniform length S - P)
            logits = jnp.repeat(logits1, B, axis=0)
            for t in range(P, S):
                logits, cache = self._decode(tokens[:, t], cache)
                self.stats.decode_tokens += B
        else:
            logits, cache = self._prefill(tokens, extras[0] if any(extras)
                                          else {})
            cache = self.model.extend_cache(cache, max_new)
            self.stats.prefill_tokens += B * S

        # ---- sampling loop ------------------------------------------------
        rng = jax.random.PRNGKey(self.seed)
        outs = [[] for _ in range(B)]
        for step in range(max_new):
            rng, sub = jax.random.split(rng)
            nxt = sample(logits, sub, temperature=temperature,
                         vocab_size=self.cfg.vocab_size)
            for b in range(B):
                outs[b].append(int(nxt[b]))
            if step + 1 < max_new:
                logits, cache = self._decode(nxt, cache)
                self.stats.decode_tokens += B
        return outs
