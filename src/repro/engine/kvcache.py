"""Paged KV cache — the TPU adaptation of PagedAttention (DESIGN.md §2).

GPU PagedAttention chases per-page pointers inside the kernel; TPUs want
dense DMA.  Layout here: one DEVICE array per layer-stack of shape
``(num_layers, num_pages, page_size, kv_heads, head_dim)`` plus an
integer page table per sequence.  The page storage is device-resident
(jnp): prefill scatters KV rows into freshly allocated pages, decode
scatters one token per sequence per step at ``(page, offset)`` computed
from the page table, and the paged decode-attention kernel (or the XLA
device gather it falls back to) reads the pages in place — the KV bytes
never round-trip through the host.  Only METADATA lives on the host:
refcounts, the free list, per-sequence page tables and lengths.

This is the authoritative KV store behind the continuous-batching
``InferenceEngine``: every full-attention transformer sequence lives
here from admission to retirement.  Host staging happens exactly at the
migration boundary (``export_sequence``/``import_sequence`` — the
cross-worker wire format), never on the decode path.

Prefix sharing: pages are REFCOUNTED.  When a new sequence's prompt hits
a cached prefix (the engine's radix tree), its page table aliases the
donor's pages — the shared prefix is stored (and was computed) exactly
once.  Full pages are immutable, so aliasing them needs no copy; a
*partial* trailing page may be aliased too (the prefix need not be
page-aligned), in which case the first append by EITHER sequence into a
page with refcount > 1 triggers copy-on-write (a device-side page copy),
so neither sequence can corrupt the other's tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass
class SequenceEntry:
    seq_id: int
    page_ids: List[int]
    length: int                      # tokens written


class PagedKVCache:  # requires: InferenceEngine._cv | engine-loop
    """Device-resident paged KV store for ONE layer-stacked model.

    Thread contract: the cache has no lock of its own — every method
    runs either on the owning engine's loop thread or under
    ``InferenceEngine._cv`` (the engine's step-gap protocol serializes
    the two; DESIGN.md §11)."""

    def __init__(self, num_layers: int, num_pages: int, page_size: int,
                 kv_heads: int, head_dim: int, dtype=jnp.float32):
        self.num_layers = num_layers
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.dtype = jnp.dtype(dtype)
        # (L, P, page, Hkv, Dh) — jnp on DEVICE; host never holds the KV
        shape = (num_layers, num_pages, page_size, kv_heads, head_dim)
        self.k = jnp.zeros(shape, self.dtype)   # memspace: device
        self.v = jnp.zeros(shape, self.dtype)   # memspace: device
        self.refcount = np.zeros((num_pages,), np.int64)  # memspace: host
        self.free_pages: List[int] = list(range(num_pages - 1, -1, -1))
        self.sequences: Dict[int, SequenceEntry] = {}
        self._next_seq = 0
        # stats
        self.pages_shared = 0
        self.tokens_reused = 0

    # ------------------------------------------------------------ alloc/free
    def _alloc_page(self) -> int:
        if not self.free_pages:
            raise MemoryError("KV cache out of pages")
        p = self.free_pages.pop()
        self.refcount[p] = 1
        return p

    def _ref_page(self, p: int) -> None:
        self.refcount[p] += 1

    def _unref_page(self, p: int) -> None:
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            self.free_pages.append(p)

    @property
    def pages_in_use(self) -> int:
        return int((self.refcount > 0).sum())

    # ----------------------------------------------------- device plumbing
    def _page_blocks(self, a) -> jnp.ndarray:
        """(L, S, Hkv, Dh) -> (L, n_pages, page, Hkv, Dh), zero-padded to
        whole pages, in pool dtype on device."""
        a = jnp.asarray(a, self.dtype)
        S = a.shape[1]
        ps = self.page_size
        n = -(-S // ps)
        pad = n * ps - S
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return a.reshape(self.num_layers, n, ps, self.kv_heads, self.head_dim)

    def _write_pages(self, pages: List[int], k, v) -> None:
        """Scatter whole-page blocks into freshly allocated pages."""
        idx = jnp.asarray(pages, jnp.int32)
        self.k = self.k.at[:, idx].set(self._page_blocks(k))
        self.v = self.v.at[:, idx].set(self._page_blocks(v))

    def _cow_last_page(self, e: SequenceEntry) -> int:
        """Make the trailing page of ``e`` private (device page copy when
        it is aliased); returns the (possibly new) page id."""
        p = e.page_ids[-1]
        if self.refcount[p] > 1:                 # copy-on-write partial page
            newp = self._alloc_page()
            self.k = self.k.at[:, newp].set(self.k[:, p])
            self.v = self.v.at[:, newp].set(self.v[:, p])
            self._unref_page(p)
            e.page_ids[-1] = newp
            p = newp
        return p

    def adopt_pages(self, k, v) -> None:
        """Install updated pool arrays returned by a jitted step that
        scattered this step's KV in place (the paged decode path: the
        pool is an input/output of the decode jit, donated on device)."""
        self.k = k
        self.v = v

    # --------------------------------------------------------------- write
    def add_sequence(self, k=None, v=None,
                     shared_from: Optional[int] = None,
                     shared_len: int = 0) -> int:
        """Store a prefilled sequence's KV. k/v: (L, S, Hkv, Dh) arrays
        (jnp device rows from prefill, or numpy at the import staging
        boundary) or None.

        If ``shared_from`` names an existing sequence, its first
        ``shared_len`` tokens are aliased.  A non-page-aligned
        ``shared_len`` additionally aliases the donor's *partial* page;
        that page stays copy-on-write protected, so the caller must then
        pass no bulk suffix (k is None / empty) and extend the sequence
        via :meth:`extend_sequence` / :meth:`append_token`, which perform
        the COW copy before the first private write.  Page-aligned
        sharing may carry a bulk suffix in k/v as before.
        """
        ps = self.page_size
        seq_id = self._next_seq
        self._next_seq += 1
        page_ids: List[int] = []
        length = 0

        if shared_from is not None and shared_len:
            donor = self.sequences[shared_from]
            assert donor.length >= shared_len
            n_full, tail = divmod(shared_len, ps)
            n_alias = n_full + (1 if tail else 0)
            for p in donor.page_ids[:n_alias]:
                self._ref_page(p)
                page_ids.append(p)
            length = shared_len
            self.pages_shared += n_alias
            self.tokens_reused += shared_len

        S = 0 if k is None else k.shape[1]
        if S:
            assert length % ps == 0, \
                "bulk suffix requires a page-aligned shared prefix; " \
                "extend_sequence() handles the copy-on-write case"
            pages = [self._alloc_page() for _ in range(-(-S // ps))]
            self._write_pages(pages, k, v)
            page_ids.extend(pages)
            length += S
        self.sequences[seq_id] = SequenceEntry(seq_id, page_ids, length)
        return seq_id

    def extend_sequence(self, seq_id: int, k, v) -> None:
        """Append a bulk KV block (L, S, Hkv, Dh) at the sequence tail.

        Fills the trailing partial page first (copy-on-write if it is
        aliased), then scatters whole pages — O(1) device calls however
        long the block, which is how chunked prefill writes its suffix
        through the pool without a per-token loop."""
        e = self.sequences[seq_id]
        k = jnp.asarray(k, self.dtype)
        v = jnp.asarray(v, self.dtype)
        S = k.shape[1]
        ps = self.page_size
        off = e.length % ps
        if off and S:
            p = self._cow_last_page(e)
            n = min(ps - off, S)
            self.k = self.k.at[:, p, off:off + n].set(k[:, :n])
            self.v = self.v.at[:, p, off:off + n].set(v[:, :n])
            e.length += n
            k, v = k[:, n:], v[:, n:]
            S -= n
        if S:
            pages = [self._alloc_page() for _ in range(-(-S // ps))]
            self._write_pages(pages, k, v)
            e.page_ids.extend(pages)
            e.length += S

    def append_token(self, seq_id: int, k_t, v_t) -> None:
        """k_t/v_t: (L, Hkv, Dh) — one decode step's KV (device scatter)."""
        e = self.sequences[seq_id]
        p, slot = self.prepare_append(seq_id)
        self.k = self.k.at[:, p, slot].set(jnp.asarray(k_t, self.dtype))
        self.v = self.v.at[:, p, slot].set(jnp.asarray(v_t, self.dtype))
        e.length += 1

    def append_tokens(self, seq_ids: List[int], k_t, v_t) -> None:
        """One decode step's KV for a whole batch: k_t/v_t (L, B, Hkv, Dh).

        Allocates / copy-on-writes each sequence's trailing page, then
        lands every row in ONE device scatter (the dense-view reference
        path's append; the paged path scatters inside the decode jit)."""
        pages, slots = [], []
        for sid in seq_ids:
            p, s = self.prepare_append(sid)
            pages.append(p)
            slots.append(s)
        pi = jnp.asarray(pages, jnp.int32)
        si = jnp.asarray(slots, jnp.int32)
        self.k = self.k.at[:, pi, si].set(jnp.asarray(k_t, self.dtype))
        self.v = self.v.at[:, pi, si].set(jnp.asarray(v_t, self.dtype))
        for sid in seq_ids:
            self.sequences[sid].length += 1

    def prepare_append(self, seq_id: int) -> Tuple[int, int]:
        """Host-metadata half of a one-token append: allocate the next
        page at a boundary, copy-on-write an aliased trailing page, and
        return the ``(page, offset)`` the token's KV must land at.  The
        caller writes the KV (device scatter — possibly inside a jitted
        decode step) and then bumps the length via
        :meth:`commit_append`."""
        e = self.sequences[seq_id]
        slot = e.length % self.page_size
        if slot == 0:
            e.page_ids.append(self._alloc_page())
            return e.page_ids[-1], 0
        return self._cow_last_page(e), slot

    def commit_append(self, seq_id: int, n: int = 1) -> None:
        self.sequences[seq_id].length += n

    def prepare_appends(self, seq_ids: List[int]
                        ) -> Tuple[List[int], List[int]]:
        """Batch :meth:`prepare_append` — the host-metadata half of one
        decode step for a whole batch.  After this, every returned page
        is PRIVATE to its sequence (refcount 1: boundary rows got a
        fresh page, aliased trailing pages were copy-on-written), which
        is the safety contract the fused append+attend kernel relies on
        to write ``(page, offset)`` slots inside the attention dispatch.
        Returns ``(pages, offsets)`` parallel to ``seq_ids``."""
        pages, offsets = [], []
        for sid in seq_ids:
            p, o = self.prepare_append(sid)
            pages.append(p)
            offsets.append(o)
        return pages, offsets

    def commit_appends(self, seq_ids: List[int], n: int = 1) -> None:
        """Batch :meth:`commit_append`: bump lengths once the step that
        wrote the prepared slots (scatter or fused kernel) has run."""
        for sid in seq_ids:
            self.commit_append(sid, n)

    # --------------------------------------------------------------- read
    def gather(self, seq_id: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Contiguous (L, T, Hkv, Dh) DEVICE views for a sequence (an
        on-device page gather; nothing crosses the host boundary)."""
        e = self.sequences[seq_id]
        idx = jnp.asarray(e.page_ids, jnp.int32)
        L, H, D = self.num_layers, self.kv_heads, self.head_dim
        k = self.k[:, idx].reshape(L, -1, H, D)
        v = self.v[:, idx].reshape(L, -1, H, D)
        return k[:, :e.length], v[:, :e.length]

    def page_table(self, seq_id: int) -> List[int]:
        return list(self.sequences[seq_id].page_ids)

    # --------------------------------------------------------- migration
    # memspace: staging (the allowlisted D2H boundary for migration)
    def export_sequence(self, seq_id: int,
                        length: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Contiguous (L, T, Hkv, Dh) HOST COPIES of a sequence's first
        ``length`` tokens (default: all of them) — the wire format for
        cross-worker KV migration, and the ONLY device->host staging
        point in the pool.  Copies (not views) so the exported block
        stays valid after the source evicts or COWs the pages."""
        e = self.sequences[seq_id]
        n = e.length if length is None else min(length, e.length)
        L, H, D = self.num_layers, self.kv_heads, self.head_dim
        if n == 0:
            z = np.zeros((L, 0, H, D), np.float32)
            return z, z.copy()
        idx = jnp.asarray(e.page_ids[:-(-n // self.page_size)], jnp.int32)
        out_k = np.asarray(self.k[:, idx].reshape(L, -1, H, D)[:, :n],
                           np.float32)
        out_v = np.asarray(self.v[:, idx].reshape(L, -1, H, D)[:, :n],
                           np.float32)
        return out_k, out_v

    # memspace: staging (the allowlisted H2D boundary for migration)
    def import_sequence(self, k: np.ndarray, v: np.ndarray) -> int:
        """Adopt a migrated contiguous KV block: allocate pages, scatter
        the tokens in (the host->device staging point), refcount them,
        and register a new sequence.  The inverse of
        :meth:`export_sequence`; raises MemoryError if the pool cannot
        hold it (callers pre-check free pages)."""
        if k.shape != v.shape or k.shape[0] != self.num_layers \
                or k.shape[2:] != (self.kv_heads, self.head_dim):
            raise ValueError(
                f"imported KV shape {k.shape} does not match cache layout "
                f"(L={self.num_layers}, Hkv={self.kv_heads}, "
                f"Dh={self.head_dim})")
        return self.add_sequence(k=k, v=v)

    def free_sequence(self, seq_id: int) -> None:
        e = self.sequences.pop(seq_id)
        for p in e.page_ids:
            self._unref_page(p)

    # --------------------------------------------------------------- sizing
    def hbm_bytes(self, dtype_bytes: Optional[int] = None) -> int:
        """Pool footprint in bytes.  Defaults to the POOL's element
        width — the old ``=2`` default silently assumed bf16 while the
        pool allocates f32, undercounting by 2x."""
        if dtype_bytes is None:
            dtype_bytes = self.dtype.itemsize
        return 2 * self.num_layers * self.num_pages * self.page_size \
            * self.kv_heads * self.head_dim * dtype_bytes
