"""Paged KV cache — the TPU adaptation of PagedAttention (DESIGN.md §2).

GPU PagedAttention chases per-page pointers inside the kernel; TPUs want
dense DMA.  Layout here: one array per layer of shape
``(num_pages, page_size, kv_heads, head_dim)`` plus an integer page table
per sequence.  ``gather()`` materializes a sequence's KV as a contiguous
``(T, kv_heads, head_dim)`` block (a dense gather XLA turns into efficient
dynamic-slices), which the decode kernel then streams through VMEM.

This is the authoritative KV store behind the continuous-batching
``InferenceEngine``: every full-attention transformer sequence lives here
from admission to retirement, and the engine's dense decode batch is a
materialized *view* over these pages (rebuilt whenever the batch
composition changes, appended in lock-step with the pages otherwise).

Prefix sharing: pages are REFCOUNTED.  When a new sequence's prompt hits
a cached prefix (the engine's radix tree), its page table aliases the
donor's pages — the shared prefix is stored (and was computed) exactly
once.  Full pages are immutable, so aliasing them needs no copy; a
*partial* trailing page may be aliased too (the prefix need not be
page-aligned), in which case the first append by EITHER sequence into a
page with refcount > 1 triggers copy-on-write, so neither sequence can
corrupt the other's tokens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class SequenceEntry:
    seq_id: int
    page_ids: List[int]
    length: int                      # tokens written


class PagedKVCache:
    """Host-managed paged KV store for ONE layer-stacked model."""

    def __init__(self, num_layers: int, num_pages: int, page_size: int,
                 kv_heads: int, head_dim: int, dtype=np.float32):
        self.num_layers = num_layers
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        # (L, P, page, Hkv, Dh) — numpy on host; device transfer on gather
        shape = (num_layers, num_pages, page_size, kv_heads, head_dim)
        self.k = np.zeros(shape, dtype)
        self.v = np.zeros(shape, dtype)
        self.refcount = np.zeros((num_pages,), np.int64)
        self.free_pages: List[int] = list(range(num_pages - 1, -1, -1))
        self.sequences: Dict[int, SequenceEntry] = {}
        self._next_seq = 0
        # stats
        self.pages_shared = 0
        self.tokens_reused = 0

    # ------------------------------------------------------------ alloc/free
    def _alloc_page(self) -> int:
        if not self.free_pages:
            raise MemoryError("KV cache out of pages")
        p = self.free_pages.pop()
        self.refcount[p] = 1
        return p

    def _ref_page(self, p: int) -> None:
        self.refcount[p] += 1

    def _unref_page(self, p: int) -> None:
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            self.free_pages.append(p)

    @property
    def pages_in_use(self) -> int:
        return int((self.refcount > 0).sum())

    # --------------------------------------------------------------- write
    def add_sequence(self, k: Optional[np.ndarray] = None,
                     v: Optional[np.ndarray] = None,
                     shared_from: Optional[int] = None,
                     shared_len: int = 0) -> int:
        """Store a prefilled sequence's KV. k/v: (L, S, Hkv, Dh) or None.

        If ``shared_from`` names an existing sequence, its first
        ``shared_len`` tokens are aliased.  A non-page-aligned
        ``shared_len`` additionally aliases the donor's *partial* page;
        that page stays copy-on-write protected, so the caller must then
        pass no bulk suffix (k is None / empty) and extend the sequence
        via :meth:`append_token`, which performs the COW copy before the
        first private write.  Page-aligned sharing may carry a bulk
        suffix in k/v as before.
        """
        ps = self.page_size
        seq_id = self._next_seq
        self._next_seq += 1
        page_ids: List[int] = []
        length = 0

        if shared_from is not None and shared_len:
            donor = self.sequences[shared_from]
            assert donor.length >= shared_len
            n_full, tail = divmod(shared_len, ps)
            n_alias = n_full + (1 if tail else 0)
            for p in donor.page_ids[:n_alias]:
                self._ref_page(p)
                page_ids.append(p)
            length = shared_len
            self.pages_shared += n_alias
            self.tokens_reused += shared_len

        S = 0 if k is None else k.shape[1]
        if S:
            assert length % ps == 0, \
                "bulk suffix requires a page-aligned shared prefix; " \
                "append_token() handles the copy-on-write case"
            for s0 in range(0, S, ps):
                p = self._alloc_page()
                n = min(ps, S - s0)
                self.k[:, p, :n] = k[:, s0:s0 + n]
                self.v[:, p, :n] = v[:, s0:s0 + n]
                page_ids.append(p)
            length += S
        self.sequences[seq_id] = SequenceEntry(seq_id, page_ids, length)
        return seq_id

    def append_token(self, seq_id: int, k_t: np.ndarray, v_t: np.ndarray) -> None:
        """k_t/v_t: (L, Hkv, Dh) — one decode step's KV."""
        e = self.sequences[seq_id]
        slot = e.length % self.page_size
        if slot == 0:
            e.page_ids.append(self._alloc_page())
        p = e.page_ids[-1]
        if self.refcount[p] > 1:                 # copy-on-write partial page
            newp = self._alloc_page()
            self.k[:, newp] = self.k[:, p]
            self.v[:, newp] = self.v[:, p]
            self._unref_page(p)
            e.page_ids[-1] = newp
            p = newp
        self.k[:, p, slot] = k_t
        self.v[:, p, slot] = v_t
        e.length += 1

    # --------------------------------------------------------------- read
    def gather(self, seq_id: int) -> Tuple[np.ndarray, np.ndarray]:
        """Contiguous (L, T, Hkv, Dh) views for a sequence."""
        e = self.sequences[seq_id]
        k = self.k[:, e.page_ids].reshape(
            self.num_layers, -1, self.kv_heads, self.head_dim)
        v = self.v[:, e.page_ids].reshape(
            self.num_layers, -1, self.kv_heads, self.head_dim)
        return k[:, :e.length], v[:, :e.length]

    def page_table(self, seq_id: int) -> List[int]:
        return list(self.sequences[seq_id].page_ids)

    # --------------------------------------------------------- migration
    def export_sequence(self, seq_id: int,
                        length: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Contiguous (L, T, Hkv, Dh) COPIES of a sequence's first
        ``length`` tokens (default: all of them) — the wire format for
        cross-worker KV migration.  Copies (not views) so the exported
        block stays valid after the source evicts or COWs the pages."""
        e = self.sequences[seq_id]
        n = e.length if length is None else min(length, e.length)
        ps = self.page_size
        shape = (self.num_layers, n, self.kv_heads, self.head_dim)
        out_k = np.empty(shape, self.k.dtype)
        out_v = np.empty(shape, self.v.dtype)
        for j, p in enumerate(e.page_ids[:-(-n // ps)] if n else []):
            lo = j * ps
            m = min(ps, n - lo)
            out_k[:, lo:lo + m] = self.k[:, p, :m]
            out_v[:, lo:lo + m] = self.v[:, p, :m]
        return out_k, out_v

    def import_sequence(self, k: np.ndarray, v: np.ndarray) -> int:
        """Adopt a migrated contiguous KV block: allocate pages, write
        the tokens in, refcount them, and register a new sequence.  The
        inverse of :meth:`export_sequence`; raises MemoryError if the
        pool cannot hold it (callers pre-check free pages)."""
        if k.shape != v.shape or k.shape[0] != self.num_layers \
                or k.shape[2:] != (self.kv_heads, self.head_dim):
            raise ValueError(
                f"imported KV shape {k.shape} does not match cache layout "
                f"(L={self.num_layers}, Hkv={self.kv_heads}, "
                f"Dh={self.head_dim})")
        return self.add_sequence(k=np.asarray(k, self.k.dtype),
                                 v=np.asarray(v, self.v.dtype))

    def free_sequence(self, seq_id: int) -> None:
        e = self.sequences.pop(seq_id)
        for p in e.page_ids:
            self._unref_page(p)

    # --------------------------------------------------------------- sizing
    def hbm_bytes(self, dtype_bytes: int = 2) -> int:
        return 2 * self.num_layers * self.num_pages * self.page_size \
            * self.kv_heads * self.head_dim * dtype_bytes
