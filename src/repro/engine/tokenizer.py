"""Deterministic hash tokenizer for real-mode end-to-end runs.

Halo is semantics-preserving at the SYSTEM level: what matters for the
reproduction is that identical prompts produce identical token streams
(so coalescing/batching can be verified bit-exact), not linguistic
quality.  A stable per-word hash into the model vocab provides exactly
that, with zero external assets.
"""
from __future__ import annotations

import hashlib
from typing import List

BOS = 1
EOS = 2
_RESERVED = 8          # ids [0, 8) reserved: pad/bos/eos/...


def _word_id(word: str, vocab_size: int) -> int:
    h = hashlib.blake2b(word.encode("utf-8"), digest_size=8).digest()
    return _RESERVED + int.from_bytes(h, "little") % (vocab_size - _RESERVED)


def tokenize(text: str, vocab_size: int, add_bos: bool = True) -> List[int]:
    toks = [BOS] if add_bos else []
    toks += [_word_id(w, vocab_size) for w in text.split()]
    return toks


def detokenize(tokens: List[int]) -> str:
    return " ".join(f"t{t}" for t in tokens)
