"""MiniDB — in-memory relational engine (the offline PostgreSQL stand-in).

Preserves the execution characteristics Halo schedules around:
* queries are genuinely CPU-bound Python row scans (I/O-ish latency);
* hash indexes give point lookups a real fast path (index vs seq scan);
* EXPLAIN returns a cost estimate (rows × per-row cost, index-aware) —
  the hook the OperatorProfiler uses for SQL T_prep estimates;
* prepared statements: parse once, bind many (reused within an epoch).

SQL subset (everything the W1–W6 workloads need):
  SELECT col | agg(col) [, ...] FROM t [JOIN t2 ON a = b]
  [WHERE col OP val [AND ...]] [GROUP BY col]
  [ORDER BY col [DESC]] [LIMIT n]
with OP ∈ {=, !=, <, <=, >, >=}; aggregates SUM/AVG/COUNT/MIN/MAX.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# per-row scan cost used by EXPLAIN (calibrated to this container's python)
SEQ_ROW_COST = 2.0e-7
INDEX_PROBE_COST = 2.0e-6
OUTPUT_ROW_COST = 5.0e-7


@dataclass
class Table:
    name: str
    columns: List[str]
    rows: List[tuple] = field(default_factory=list)
    indexes: Dict[str, Dict[Any, List[int]]] = field(default_factory=dict)

    def col_ix(self, col: str) -> int:
        return self.columns.index(col)

    def build_index(self, col: str) -> None:
        ix = self.col_ix(col)
        index: Dict[Any, List[int]] = {}
        for i, r in enumerate(self.rows):
            index.setdefault(r[ix], []).append(i)
        self.indexes[col] = index


# ---------------------------------------------------------------------------
# parsing
# ---------------------------------------------------------------------------

_AGG = re.compile(r"^(sum|avg|count|min|max)\((\*|[\w.]+)\)$", re.I)
_Q = re.compile(
    r"^\s*select\s+(?P<select>.+?)\s+from\s+(?P<table>\w+)"
    r"(?:\s+join\s+(?P<join>\w+)\s+on\s+(?P<jl>[\w.]+)\s*=\s*(?P<jr>[\w.]+))?"
    r"(?:\s+where\s+(?P<where>.+?))?"
    r"(?:\s+group\s+by\s+(?P<group>[\w.]+))?"
    r"(?:\s+order\s+by\s+(?P<order>[\w.]+)(?P<desc>\s+desc)?)?"
    r"(?:\s+limit\s+(?P<limit>\d+))?\s*;?\s*$",
    re.I | re.S)
_COND = re.compile(r"([\w.]+)\s*(<=|>=|!=|=|<|>)\s*('(?:[^']*)'|[-\w.]+)")

_OPS: Dict[str, Callable[[Any, Any], bool]] = {
    "=": lambda a, b: a == b, "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
}


def _parse_val(tok: str) -> Any:
    tok = tok.strip()
    if tok.startswith("'"):
        return tok[1:-1]
    try:
        return int(tok)
    except ValueError:
        try:
            return float(tok)
        except ValueError:
            return tok


@dataclass(frozen=True)
class Query:
    select: Tuple[Tuple[str, str], ...]   # (agg|'', column)
    table: str
    join: Optional[Tuple[str, str, str]]  # (table2, left_col, right_col)
    where: Tuple[Tuple[str, str, Any], ...]
    group_by: Optional[str]
    order_by: Optional[str]
    desc: bool
    limit: Optional[int]


def parse_sql(sql: str) -> Query:
    m = _Q.match(sql)
    if not m:
        raise ValueError(f"unsupported SQL: {sql!r}")
    select: List[Tuple[str, str]] = []
    for part in m.group("select").split(","):
        part = part.strip()
        am = _AGG.match(part)
        if am:
            select.append((am.group(1).lower(), am.group(2)))
        else:
            select.append(("", part))
    join = None
    if m.group("join"):
        join = (m.group("join"), m.group("jl"), m.group("jr"))
    where: List[Tuple[str, str, Any]] = []
    if m.group("where"):
        for c in re.split(r"\s+and\s+", m.group("where"), flags=re.I):
            cm = _COND.match(c.strip())
            if not cm:
                raise ValueError(f"unsupported condition: {c!r}")
            where.append((cm.group(1), cm.group(2), _parse_val(cm.group(3))))
    return Query(
        select=tuple(select), table=m.group("table"), join=join,
        where=tuple(where), group_by=m.group("group"),
        order_by=m.group("order"), desc=bool(m.group("desc")),
        limit=int(m.group("limit")) if m.group("limit") else None)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

class MiniDB:
    def __init__(self):
        self.tables: Dict[str, Table] = {}
        self._prepared: Dict[str, Query] = {}
        # stats
        self.queries_executed = 0
        self.rows_scanned = 0
        self.prepared_hits = 0

    # ---------------------------------------------------------------- schema
    def create_table(self, name: str, columns: Sequence[str],
                     rows: Sequence[tuple]) -> None:
        self.tables[name] = Table(name, list(columns), [tuple(r) for r in rows])

    def create_index(self, table: str, col: str) -> None:
        self.tables[table].build_index(col)

    # ----------------------------------------------------------------- helpers
    def _resolve(self, col: str, t1: Table, t2: Optional[Table]
                 ) -> Tuple[int, int]:
        """column → (source: 0|1, index). Supports table-qualified names."""
        if "." in col:
            tname, c = col.split(".", 1)
            if tname == t1.name:
                return 0, t1.col_ix(c)
            if t2 is not None and tname == t2.name:
                return 1, t2.col_ix(c)
            raise KeyError(f"unknown table in {col!r}")
        if col in t1.columns:
            return 0, t1.col_ix(col)
        if t2 is not None and col in t2.columns:
            return 1, t2.col_ix(col)
        raise KeyError(f"unknown column {col!r}")

    # ----------------------------------------------------------------- execute
    def prepare(self, sql: str) -> Query:
        q = self._prepared.get(sql)
        if q is None:
            q = parse_sql(sql)
            self._prepared[sql] = q
        else:
            self.prepared_hits += 1
        return q

    def execute(self, sql: str) -> List[tuple]:
        return self.execute_query(self.prepare(sql))

    def execute_query(self, q: Query) -> List[tuple]:
        self.queries_executed += 1
        t1 = self.tables[q.table]
        t2 = self.tables[q.join[0]] if q.join else None

        # --- base scan with pushed-down single-table predicates ----------
        eq_pred = next(((c, v) for c, op, v in q.where
                        if op == "=" and self._pred_on_base(c, t1, t2)
                        and self._col_name(c) in t1.indexes), None)
        if eq_pred is not None:
            col, val = eq_pred
            idx = t1.indexes[self._col_name(col)]
            base_ids = idx.get(val, [])
            base_rows = [t1.rows[i] for i in base_ids]
            self.rows_scanned += len(base_rows) + 1
        else:
            base_rows = t1.rows
            self.rows_scanned += len(t1.rows)

        # --- join ----------------------------------------------------------
        if t2 is not None:
            jt, jl, jr = q.join
            sl, li = self._resolve(jl, t1, t2)
            sr, ri = self._resolve(jr, t1, t2)
            if sl != 0:                     # normalize: left col on t1
                li, ri = ri, li
            right_col = t2.columns[ri]
            if right_col not in t2.indexes:
                t2.build_index(right_col)
            ridx = t2.indexes[right_col]
            joined: List[tuple] = []
            for r in base_rows:
                for j in ridx.get(r[li], ()):
                    joined.append(r + t2.rows[j])
                    self.rows_scanned += 1
            rows = joined
            columns_all = t1.columns + t2.columns
            # resolver over the concatenated row
            def col_ix(col: str) -> int:
                s, i = self._resolve(col, t1, t2)
                return i if s == 0 else len(t1.columns) + i
        else:
            rows = list(base_rows)
            def col_ix(col: str) -> int:
                return self._resolve(col, t1, None)[1]

        # --- residual filters ----------------------------------------------
        for col, op, val in q.where:
            if eq_pred is not None and (col, val) == eq_pred and op == "=":
                continue
            ix = col_ix(col)
            f = _OPS[op]
            rows = [r for r in rows if r[ix] is not None and f(r[ix], val)]

        # --- group by / aggregates -----------------------------------------
        if q.group_by or any(a for a, _ in q.select):
            rows = self._aggregate(q, rows, col_ix)
        else:
            ixs = [col_ix(c) for _, c in q.select]
            rows = [tuple(r[i] for i in ixs) for r in rows]

        # --- order / limit ---------------------------------------------------
        if q.order_by:
            out_cols = [c for _, c in q.select]
            if q.order_by in out_cols:
                key_ix = out_cols.index(q.order_by)
                rows.sort(key=lambda r: r[key_ix], reverse=q.desc)
            # ordering by a non-projected column after aggregation: skip
        if q.limit is not None:
            rows = rows[:q.limit]
        return rows

    def _pred_on_base(self, col: str, t1: Table, t2: Optional[Table]) -> bool:
        try:
            return self._resolve(col, t1, t2)[0] == 0
        except KeyError:
            return False

    @staticmethod
    def _col_name(col: str) -> str:
        return col.split(".", 1)[1] if "." in col else col

    def _aggregate(self, q: Query, rows: List[tuple],
                   col_ix: Callable[[str], int]) -> List[tuple]:
        groups: Dict[Any, List[tuple]] = {}
        if q.group_by:
            gix = col_ix(q.group_by)
            for r in rows:
                groups.setdefault(r[gix], []).append(r)
        else:
            groups[None] = rows

        def agg_val(agg: str, col: str, rs: List[tuple]) -> Any:
            if agg == "count":
                return len(rs)
            vals = [r[col_ix(col)] for r in rs]
            vals = [v for v in vals if v is not None]
            if not vals:
                return None
            if agg == "sum":
                return sum(vals)
            if agg == "avg":
                return sum(vals) / len(vals)
            if agg == "min":
                return min(vals)
            if agg == "max":
                return max(vals)
            raise ValueError(agg)

        out: List[tuple] = []
        for key in sorted(groups, key=lambda k: (k is None, k)):
            rs = groups[key]
            row: List[Any] = []
            for agg, col in q.select:
                if agg:
                    row.append(agg_val(agg, col, rs))
                elif q.group_by and col == q.group_by:
                    row.append(key)
                else:
                    row.append(rs[0][col_ix(col)])
            out.append(tuple(row))
        return out

    # ----------------------------------------------------------------- explain
    def explain(self, sql: str) -> float:
        """Cost estimate in seconds (the EXPLAIN hook for the profiler)."""
        try:
            q = self.prepare(sql)
        except (ValueError, KeyError):
            return 0.05
        t1 = self.tables.get(q.table)
        if t1 is None:
            return 0.05
        n = len(t1.rows)
        uses_index = any(
            op == "=" and self._col_name(c) in t1.indexes
            for c, op, v in q.where)
        if uses_index:
            # selectivity estimate: uniform distribution over index keys
            col = next(self._col_name(c) for c, op, v in q.where
                       if op == "=" and self._col_name(c) in t1.indexes)
            nkeys = max(len(t1.indexes[col]), 1)
            est_rows = max(n // nkeys, 1)
            cost = INDEX_PROBE_COST + est_rows * OUTPUT_ROW_COST
        else:
            est_rows = n
            cost = n * SEQ_ROW_COST
        if q.join:
            t2 = self.tables.get(q.join[0])
            fan = 2.0 if t2 is None else max(len(t2.rows) / max(n, 1), 1.0)
            cost += est_rows * min(fan, 4.0) * OUTPUT_ROW_COST
        if q.group_by:
            cost += est_rows * OUTPUT_ROW_COST
        return cost
