"""W1–W6 + W+ workflow library (Table 3 topologies).

Node counts match the paper exactly — #Nodes (LLM/CPU):
  W1 IMDb-Diamond 8/9 · W2 IMDb-TripleChain 10/3 · W3 FineWiki-LongChain 9/6
  W4 FineWiki-Bridge 9/3 · W5 TPCH-Trident 7/9 · W6 TPCH-Fanout 9/12
  W+ linear LLM-only chain 3/0.

Each builder returns (workflow dict, binding sampler).  Binding pools are
deliberately small relative to N so batches carry the cross-query
redundancy (repeated SQL templates, identical API calls) that Halo's
request coalescing exploits — the workload property §6.2 measures.
"""
from __future__ import annotations

import random
from typing import Callable, Dict, List, Sequence, Tuple

from repro.workloads.datagen import GENRES, MARKETS, SEGMENTS

M14, M32, M20 = "qwen3-14b", "qwen3-32b", "gpt-oss-20b"

WorkloadBuilder = Callable[[], Tuple[dict, Callable[[int, int], List[Dict]]]]


def _bind_sampler(pool_fn: Callable[[random.Random], Dict]
                  ) -> Callable[[int, int], List[Dict]]:
    def sample(n: int, seed: int = 0) -> List[Dict]:
        rng = random.Random(seed)
        return [pool_fn(rng) for _ in range(n)]
    return sample


# ---------------------------------------------------------------------------
def w1_imdb_diamond() -> Tuple[dict, Callable]:
    """Diamond: plan → 3 searchers (join-heavy SQL ×2 each) → 3 analyzers
    (SQL ×1 each) → edit.  8 LLM / 9 CPU."""
    nodes = [
        {"id": "plan", "type": "llm", "model": M14, "max_new_tokens": 24,
         "est_prompt_tokens": 96,
         "prompt": "Plan an investigation of $genre movies after $year."},
    ]
    for i in range(3):
        nodes.append({
            "id": f"search{i}", "type": "llm", "model": [M14, M20, M32][i],
            "max_new_tokens": 48, "est_prompt_tokens": 256,
            "prompt": (
                f"Branch {i}: given ${{plan}}, summarize "
                "{{sql: SELECT title, rating FROM titles WHERE genre='$genre' "
                "AND year >= $year ORDER BY rating DESC LIMIT 10}} and cast "
                "{{sql: SELECT people.name FROM crew JOIN people ON "
                "crew.person_id = people.id WHERE crew.title_id = $tid "
                f"LIMIT 10}}}} for aspect {i}.")})
        nodes.append({
            "id": f"analyze{i}", "type": "llm", "model": [M32, M14, M20][i],
            "max_new_tokens": 64, "est_prompt_tokens": 320,
            "prompt": (
                f"Attribute findings of ${{search{i}}} using "
                "{{sql: SELECT count(*), avg(rating) FROM titles WHERE "
                f"genre='$genre'}}}} for aspect {i}.")})
    nodes.append({
        "id": "edit", "type": "llm", "model": M32, "max_new_tokens": 96,
        "est_prompt_tokens": 512,
        "prompt": "Synthesize ${analyze0} ${analyze1} ${analyze2}."})
    wf = {"name": "W1-IMDb-Diamond", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"genre": GENRES[rng.randrange(4)],
                "year": 1990 + 5 * rng.randrange(5),
                "tid": rng.randrange(64)}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def w2_imdb_triplechain() -> Tuple[dict, Callable]:
    """Three independent 3-LLM chains (movie / person / crew) merging into
    a final answer.  10 LLM / 3 CPU."""
    nodes = []
    chains = [
        ("movie", "{{sql: SELECT title, year FROM titles WHERE genre='$genre' "
                  "ORDER BY rating DESC LIMIT 5}}"),
        ("person", "{{sql: SELECT name, born FROM people WHERE born >= $born "
                   "LIMIT 5}}"),
        ("crew", "{{sql: SELECT role, count(*) FROM crew WHERE "
                 "title_id = $tid GROUP BY role}}"),
    ]
    for name, sql in chains:
        nodes.append({
            "id": f"{name}_fetch", "type": "llm", "model": M14,
            "max_new_tokens": 32, "est_prompt_tokens": 192,
            "prompt": f"Extract {name} facts from {sql}."})
        nodes.append({
            "id": f"{name}_reason", "type": "llm", "model": M14,
            "max_new_tokens": 48, "est_prompt_tokens": 224,
            "prompt": f"Reason over ${{{name}_fetch}} about $genre."})
        nodes.append({
            "id": f"{name}_draft", "type": "llm", "model": M20,
            "max_new_tokens": 48, "est_prompt_tokens": 256,
            "prompt": f"Draft a note from ${{{name}_reason}}."})
    nodes.append({
        "id": "merge", "type": "llm", "model": M32, "max_new_tokens": 96,
        "est_prompt_tokens": 512,
        "prompt": "Answer using ${movie_draft} ${person_draft} ${crew_draft}."})
    wf = {"name": "W2-IMDb-TripleChain", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"genre": GENRES[rng.randrange(6)],
                "born": 1940 + 10 * rng.randrange(4),
                "tid": rng.randrange(32)}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def w3_finewiki_longchain() -> Tuple[dict, Callable]:
    """Deep 9-LLM sequential chain; 6 steps block on DB retrievals —
    the critical-path stress test.  9 LLM / 6 CPU."""
    nodes = []
    prev = None
    for i in range(9):
        prompt = f"Step {i}: continue the investigation of topic $topic"
        if prev:
            prompt += f" given ${{{prev}}}"
        if i % 3 != 2:        # steps 0,1,3,4,6,7 → 6 retrievals
            prompt += (" with context {{sql: SELECT body FROM pages WHERE "
                       f"title = 'page_$p{i}'}}}}")
        nid = f"step{i}"
        nodes.append({"id": nid, "type": "llm",
                      "model": [M14, M20, M32][i % 3],
                      "max_new_tokens": 40, "est_prompt_tokens": 256,
                      "prompt": prompt + "."})
        prev = nid
    wf = {"name": "W3-FineWiki-LongChain", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        b = {"topic": GENRES[rng.randrange(len(GENRES))]}
        for i in range(9):
            b[f"p{i}"] = rng.randrange(256)
        return b
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def w4_finewiki_bridge() -> Tuple[dict, Callable]:
    """Main 9-LLM reasoning chain with 3 auxiliary DB lookups bridging in
    at irregular positions.  9 LLM / 3 CPU."""
    nodes = []
    prev = None
    aux_at = {2: 0, 5: 1, 7: 2}
    for i in range(9):
        prompt = f"Reason step {i} on $topic"
        if prev:
            prompt += f" from ${{{prev}}}"
        if i in aux_at:
            j = aux_at[i]
            prompt += (" plus aux {{sql: SELECT title, views FROM pages "
                       f"WHERE topic = '$aux{j}' ORDER BY views DESC "
                       "LIMIT 5}}")
        nid = f"hop{i}"
        nodes.append({"id": nid, "type": "llm",
                      "model": [M14, M14, M32][i % 3],
                      "max_new_tokens": 36, "est_prompt_tokens": 224,
                      "prompt": prompt + "."})
        prev = nid
    # irregular dependency insertion: hop3 also feeds hop8
    nodes[-1]["prompt"] += " Recall ${hop3}."
    wf = {"name": "W4-FineWiki-Bridge", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"topic": GENRES[rng.randrange(len(GENRES))],
                "aux0": GENRES[rng.randrange(4)],
                "aux1": GENRES[rng.randrange(4)],
                "aux2": GENRES[rng.randrange(4)]}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def w5_tpch_trident() -> Tuple[dict, Callable]:
    """Trident: plan → 3 concurrent analytical branches (3 TPC-H style
    aggregate SQLs each) → merge... 7 LLM / 9 CPU."""
    nodes = [
        {"id": "plan", "type": "llm", "model": M14, "max_new_tokens": 24,
         "est_prompt_tokens": 96,
         "prompt": "Plan revenue analysis for market $market."},
    ]
    branch_sql = [
        ("pricing",
         "{{sql: SELECT returnflag, sum(quantity), avg(price) FROM lineitem "
         "WHERE shipdate <= '$date' GROUP BY returnflag}}",
         "{{sql: SELECT count(*) FROM lineitem WHERE discount >= $disc}}",
         "{{sql: SELECT avg(totalprice) FROM orders WHERE "
         "orderdate >= '$date2'}}"),
        ("orders",
         "{{sql: SELECT count(*), avg(totalprice) FROM orders WHERE "
         "orderdate <= '$date'}}",
         "{{sql: SELECT segment, count(*) FROM customer WHERE "
         "market = '$market' GROUP BY segment}}",
         "{{sql: SELECT max(totalprice) FROM orders WHERE "
         "orderdate >= '$date2'}}"),
        ("volume",
         "{{sql: SELECT sum(quantity) FROM lineitem WHERE "
         "shipdate >= '$date2'}}",
         "{{sql: SELECT returnflag, count(*) FROM lineitem "
         "GROUP BY returnflag}}",
         "{{sql: SELECT count(*) FROM customer WHERE market = '$market'}}"),
    ]
    for name, s1, s2, s3 in branch_sql:
        nodes.append({
            "id": f"{name}_scan", "type": "llm",
            "model": {"pricing": M20, "orders": M14, "volume": M20}[name],
            "max_new_tokens": 48, "est_prompt_tokens": 384,
            "prompt": f"Given ${{plan}}, digest {s1} and {s2} and {s3}."})
        nodes.append({
            "id": f"{name}_judge", "type": "llm",
            "model": {"pricing": M32, "orders": M32, "volume": M14}[name],
            "max_new_tokens": 64, "est_prompt_tokens": 320,
            "prompt": f"Judge metric trends in ${{{name}_scan}}."})
    wf = {"name": "W5-TPCH-Trident", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"market": MARKETS[rng.randrange(3)],
                "date": f"199{rng.randrange(3,6)}-06-01",
                "date2": f"199{rng.randrange(0,3)}-01-01",
                "disc": round(0.02 * rng.randrange(1, 4), 2)}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def w6_tpch_fanout() -> Tuple[dict, Callable]:
    """Fan-out: broadcast (1 http) → 4 stage-1 agents (2 SQL each) →
    3 stage-2 aggregators (1 SQL each) → report.  9 LLM / 12 CPU."""
    nodes = [
        {"id": "broadcast", "type": "llm", "model": M14,
         "max_new_tokens": 24, "est_prompt_tokens": 128,
         "prompt": "Broadcast params for $market from "
                   "{{http: GET /params?market=$market&seg=$segment}}."},
    ]
    for i in range(4):
        nodes.append({
            "id": f"agent{i}", "type": "llm", "model": [M20, M14, M20, M14][i],
            "max_new_tokens": 48, "est_prompt_tokens": 384,
            "prompt": (
                f"Agent {i}: with ${{broadcast}}, analyze "
                "{{sql: SELECT segment, count(*) FROM customer WHERE "
                "market = '$market' GROUP BY segment}} and "
                "{{sql: SELECT returnflag, sum(price) FROM lineitem WHERE "
                "shipdate <= '$date' GROUP BY returnflag}}"
                f" for objective {i}.")})
    for j in range(3):
        src = " ".join(f"${{agent{i}}}" for i in range(4))
        nodes.append({
            "id": f"agg{j}", "type": "llm", "model": [M32, M20, M32][j],
            "max_new_tokens": 64, "est_prompt_tokens": 512,
            "prompt": (
                f"Aggregate metric {j} from {src} enriched by "
                f"{{{{http: GET /bench/metric{j}?market=$market}}}}.")})
    nodes.append({
        "id": "report", "type": "llm", "model": M32, "max_new_tokens": 96,
        "est_prompt_tokens": 512,
        "prompt": "Final report from ${agg0} ${agg1} ${agg2}."})
    wf = {"name": "W6-TPCH-Fanout", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"market": MARKETS[rng.randrange(3)],
                "segment": SEGMENTS[rng.randrange(3)],
                "date": f"199{rng.randrange(3,6)}-06-01"}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def wplus_linear() -> Tuple[dict, Callable]:
    """W+: lightweight LLM-only 3-node linear chain (online-serving probe)."""
    nodes = [
        {"id": "draft", "type": "llm", "model": M14, "max_new_tokens": 32,
         "est_prompt_tokens": 96, "prompt": "Draft an answer about $topic."},
        {"id": "refine", "type": "llm", "model": M14, "max_new_tokens": 32,
         "est_prompt_tokens": 160, "prompt": "Refine ${draft}."},
        {"id": "final", "type": "llm", "model": M14, "max_new_tokens": 48,
         "est_prompt_tokens": 192, "prompt": "Finalize ${refine}."},
    ]
    wf = {"name": "W+-Linear", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"topic": GENRES[rng.randrange(len(GENRES))]}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def wd_doc_draft() -> Tuple[dict, Callable]:
    """WD: retrieval-grounded briefing draft (3 LLM / 2 CPU).

    Built for MIXED batches: its context retrieval renders the same
    ``pages``-by-topic SQL template W4's aux lookups issue (topics drawn
    from the same 4-genre pool), so a multi-template batch of wd+w4
    coalesces requests ACROSS templates — the cross-template dedup the
    mega-DAG consolidation (``consolidate_multi``) exists to find.
    """
    nodes = [
        {"id": "outline", "type": "llm", "model": M14, "max_new_tokens": 24,
         "est_prompt_tokens": 128,
         "prompt": (
             "Outline a briefing on $topic using "
             "{{sql: SELECT title, views FROM pages WHERE topic = '$topic' "
             "ORDER BY views DESC LIMIT 5}} and "
             "{{sql: SELECT count(*) FROM pages WHERE topic = '$topic'}}.")},
        {"id": "draft", "type": "llm", "model": M14, "max_new_tokens": 48,
         "est_prompt_tokens": 224,
         "prompt": "Draft the briefing from ${outline}."},
        {"id": "polish", "type": "llm", "model": M32, "max_new_tokens": 48,
         "est_prompt_tokens": 256,
         "prompt": "Polish ${draft} for audience $aud."},
    ]
    wf = {"name": "WD-DocDraft", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"topic": GENRES[rng.randrange(4)],     # == W4's aux pool
                "aud": SEGMENTS[rng.randrange(3)]}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def wt_tool_pipeline() -> Tuple[dict, Callable]:
    """WT: llm → dependent tools → llm, all on one model.

    Unlike W1–W6 (whose tool args reference only bindings, so every tool
    is a DAG root), WT's tools consume the upstream LLM *output* — the
    shape where per-request CPU-GPU pipelining pays: query i's tools can
    run the moment ITS generation retires, overlapping the stragglers'
    decode, and its final-stage request joins the running batch.
    Bindings are per-query distinct so nothing coalesces away.
    """
    nodes = [
        {"id": "gen", "type": "llm", "model": M14, "max_new_tokens": 24,
         "est_prompt_tokens": 96,
         "prompt": "Angle $k: draft a claim about $topic."},
        {"id": "verify", "type": "tool", "op": "http",
         "args": "GET /api/verify?claim=${gen}&k=$k"},
        {"id": "count", "type": "tool", "op": "pyfn",
         "args": "wordcount(${gen})"},
        {"id": "final", "type": "llm", "model": M14, "max_new_tokens": 16,
         "est_prompt_tokens": 128,
         "prompt": "Finalize angle $k with ${verify} and ${count}."},
    ]
    wf = {"name": "WT-ToolPipeline", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        return {"topic": GENRES[rng.randrange(len(GENRES))],
                "k": rng.randrange(100000)}
    return wf, _bind_sampler(pool)


# ---------------------------------------------------------------------------
def ws_page_audit() -> Tuple[dict, Callable]:
    """WS: the data-scale per-row audit template (DESIGN.md §12.1).

    One query per ``pages`` row — the templated LLM-step-over-rows shape
    where an enumerator (``repro.workloads.enumerators``) produces the
    bindings from the data itself instead of a random pool.  ``fetch`` is
    a per-row indexed point lookup (distinct per query); ``stats`` is a
    per-topic aggregate shared by every query of that topic, so a
    thousands-of-query batch coalesces it down to #topics physical
    executions.  The random sampler below keeps ``build_workload("ws",
    n)`` usable standalone (titles it draws exist in the finewiki DB).
    """
    nodes = [
        {"id": "fetch", "type": "tool", "op": "sql",
         "args": "SELECT views, topic FROM pages WHERE title = '$title'"},
        {"id": "stats", "type": "tool", "op": "sql",
         "args": ("SELECT count(*), avg(views) FROM pages "
                  "WHERE topic = '$topic'")},
        {"id": "assess", "type": "llm", "model": M14, "max_new_tokens": 24,
         "est_prompt_tokens": 160,
         "prompt": ("Assess page $title using ${fetch} against the "
                    "$topic norms ${stats}.")},
        {"id": "brief", "type": "llm", "model": M32, "max_new_tokens": 16,
         "est_prompt_tokens": 192,
         "prompt": "One-line brief of ${assess} for row $rank."},
    ]
    wf = {"name": "WS-PageAudit", "nodes": nodes}

    def pool(rng: random.Random) -> Dict:
        i = rng.randrange(20000)            # datagen's finewiki page count
        return {"title": f"page_{i}",
                "topic": GENRES[rng.randrange(len(GENRES))],
                "rank": i}
    return wf, _bind_sampler(pool)


WORKFLOWS: Dict[str, WorkloadBuilder] = {
    "w1": w1_imdb_diamond,
    "w2": w2_imdb_triplechain,
    "w3": w3_finewiki_longchain,
    "w4": w4_finewiki_bridge,
    "w5": w5_tpch_trident,
    "w6": w6_tpch_fanout,
    "w+": wplus_linear,
    "wt": wt_tool_pipeline,
    "wd": wd_doc_draft,
    "ws": ws_page_audit,
}

DATABASE_OF = {
    "w1": "imdb", "w2": "imdb", "w3": "finewiki", "w4": "finewiki",
    "w5": "tpch", "w6": "tpch", "w+": "finewiki", "wt": "finewiki",
    "wd": "finewiki", "ws": "finewiki",
}

# the default MIXED online-serving blend: a doc-draft template, the
# tool-dependent pipeline, and one analytics template, all over the same
# database so one ToolRuntime serves the whole mega-DAG
MIXED_PARTS = ("wd", "wt", "w4")


def _paper_scale_estimate(op: str, args: str) -> float:
    """Latency estimate matching the PAPER's backends (PostgreSQL with
    200M-row IMDb / SF=10 TPC-H; real external APIs) rather than the
    scaled-down minidb.  Used by the simulated backend; real mode profiles
    the actual minidb instead."""
    a = args.lower()
    if op == "http":
        return 2.00                       # external API + parse
    if op == "pyfn":
        return 0.02
    if "lineitem" in a or "orders" in a:
        return 0.50                       # SF=10 analytical aggregates
    if "join" in a:
        return 0.45                       # multi-way IMDb joins
    if "pages" in a:
        return 0.03                       # B-tree point lookups
    return 0.20


def build_graph(name: str, paper_scale_estimates: bool = True):
    """Parse workload ``name``'s template alone: (GraphSpec, database
    name).  The binding-enumerator path (``repro.workloads.enumerators``)
    uses this to pair the template with data-derived bindings instead of
    the random sampler."""
    from repro.core.graphspec import GraphSpec
    from repro.core.parser import parse_workflow
    wf, _ = WORKFLOWS[name]()
    graph = parse_workflow(wf)
    if paper_scale_estimates:
        nodes = []
        for nid, spec in graph.nodes.items():
            if not spec.is_llm() and not spec.est_seconds:
                spec = spec.with_(
                    est_seconds=_paper_scale_estimate(spec.op, spec.args))
            nodes.append(spec)
        graph = GraphSpec(graph.name, nodes, graph.edges)
    return graph, DATABASE_OF[name]


def build_workload(name: str, n_queries: int, seed: int = 0,
                   paper_scale_estimates: bool = True):
    """Returns (GraphSpec, bindings, database name)."""
    graph, dbname = build_graph(
        name, paper_scale_estimates=paper_scale_estimates)
    _, sampler = WORKFLOWS[name]()
    bindings = sampler(n_queries, seed)
    return graph, bindings, dbname


def build_mixed_workload(n_queries: int, seed: int = 0,
                         parts: Sequence[str] = MIXED_PARTS,
                         paper_scale_estimates: bool = True):
    """A mixed multi-template batch: ``n_queries`` split (round-robin
    remainders first) across ``parts``.

    Returns ``(batches, database)`` where ``batches`` is the
    ``[(GraphSpec, bindings), ...]`` list ``consolidate_multi`` takes.
    Every part must live on the same database (one ToolRuntime serves
    the merged graph).
    """
    parts = list(parts)
    if not parts:
        raise ValueError("mixed workload needs at least one part")
    dbs = {DATABASE_OF[p] for p in parts}
    if len(dbs) > 1:
        raise ValueError(f"mixed parts span databases {sorted(dbs)}; "
                         "pick templates sharing one backend")
    base, rem = divmod(n_queries, len(parts))
    batches = []
    for i, part in enumerate(parts):
        n_i = base + (1 if i < rem else 0)
        g, bindings, _ = build_workload(
            part, n_i, seed=seed + i,
            paper_scale_estimates=paper_scale_estimates)
        batches.append((g, bindings))
    return batches, dbs.pop()
