"""Deterministic synthetic datasets shaped like the paper's three DBs.

IMDb-like (relational, join-heavy), FineWiki-like (point lookups over
page records), TPC-H-like (analytical aggregates).  Sizes are scaled to
CPU-runnable defaults but keep the relative shapes (lineitem largest,
crew a many-to-many bridge, pages indexed by title).
"""
from __future__ import annotations

import random

from repro.workloads.minidb import MiniDB

GENRES = ["drama", "comedy", "action", "thriller", "scifi", "horror",
          "romance", "documentary"]
ROLES = ["actor", "director", "writer", "producer"]
MARKETS = ["us", "eu", "apac", "latam", "mea"]
SEGMENTS = ["building", "automobile", "machinery", "household", "furniture"]
FLAGS = ["A", "N", "R"]


def load_imdb(db: MiniDB, scale: int = 1, seed: int = 7) -> None:
    rng = random.Random(seed)
    n_titles, n_people = 4000 * scale, 2000 * scale
    titles = [(i, f"title_{i}", 1950 + rng.randrange(75),
               GENRES[rng.randrange(len(GENRES))],
               round(rng.uniform(1.0, 10.0), 1))
              for i in range(n_titles)]
    people = [(i, f"person_{i}", 1920 + rng.randrange(90))
              for i in range(n_people)]
    crew = []
    for t in range(n_titles):
        for _ in range(rng.randrange(3, 8)):
            crew.append((t, rng.randrange(n_people),
                         ROLES[rng.randrange(len(ROLES))]))
    db.create_table("titles", ["id", "title", "year", "genre", "rating"], titles)
    db.create_table("people", ["id", "name", "born"], people)
    db.create_table("crew", ["title_id", "person_id", "role"], crew)
    db.create_index("titles", "id")
    db.create_index("titles", "genre")
    db.create_index("people", "id")
    db.create_index("crew", "title_id")
    db.create_index("crew", "person_id")


def load_finewiki(db: MiniDB, scale: int = 1, seed: int = 11) -> None:
    rng = random.Random(seed)
    n_pages = 20000 * scale
    pages = []
    for i in range(n_pages):
        words = " ".join(f"w{rng.randrange(5000)}" for _ in range(20))
        pages.append((i, f"page_{i}", words, rng.randrange(1, 100000),
                      GENRES[rng.randrange(len(GENRES))]))
    db.create_table("pages", ["id", "title", "body", "views", "topic"], pages)
    db.create_index("pages", "id")
    db.create_index("pages", "title")


def load_tpch(db: MiniDB, scale: int = 1, seed: int = 13) -> None:
    rng = random.Random(seed)
    n_cust, n_orders, n_items = 1500 * scale, 15000 * scale, 60000 * scale
    customers = [(i, f"cust_{i}", MARKETS[rng.randrange(len(MARKETS))],
                  SEGMENTS[rng.randrange(len(SEGMENTS))])
                 for i in range(n_cust)]
    orders = [(i, rng.randrange(n_cust),
               f"199{rng.randrange(8)}-{rng.randrange(1,13):02d}-01",
               round(rng.uniform(1e3, 5e5), 2))
              for i in range(n_orders)]
    lineitem = []
    for i in range(n_items):
        lineitem.append((
            i, rng.randrange(n_orders),
            rng.randrange(1, 50),                       # quantity
            round(rng.uniform(100.0, 10000.0), 2),      # price
            round(rng.uniform(0.0, 0.1), 2),            # discount
            FLAGS[rng.randrange(len(FLAGS))],           # returnflag
            f"199{rng.randrange(8)}-{rng.randrange(1,13):02d}-15"))
    db.create_table("customer", ["id", "name", "market", "segment"], customers)
    db.create_table("orders", ["id", "cust_id", "orderdate", "totalprice"], orders)
    db.create_table("lineitem",
                    ["id", "order_id", "quantity", "price", "discount",
                     "returnflag", "shipdate"], lineitem)
    db.create_index("customer", "id")
    db.create_index("customer", "market")
    db.create_index("orders", "id")
    db.create_index("orders", "cust_id")
    db.create_index("lineitem", "order_id")
    db.create_index("lineitem", "returnflag")


def build_database(which: str, scale: int = 1) -> MiniDB:
    db = MiniDB()
    if which == "imdb":
        load_imdb(db, scale)
    elif which == "finewiki":
        load_finewiki(db, scale)
    elif which == "tpch":
        load_tpch(db, scale)
    elif which == "all":
        load_imdb(db, scale)
        load_finewiki(db, scale)
        load_tpch(db, scale)
    else:
        raise ValueError(which)
    return db
