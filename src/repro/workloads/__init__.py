"""Paper workloads: minidb (PostgreSQL stand-in), synthetic datasets,
tool operators, and the W1–W6 / W+ workflow library (Table 3)."""
from repro.workloads.library import (MIXED_PARTS, WORKFLOWS,
                                     build_mixed_workload, build_workload)
from repro.workloads.minidb import MiniDB
from repro.workloads.tools import ToolRuntime

__all__ = ["MIXED_PARTS", "WORKFLOWS", "build_mixed_workload",
           "build_workload", "MiniDB", "ToolRuntime"]
