"""Paper workloads: minidb (PostgreSQL stand-in), synthetic datasets,
tool operators, the W1–W6 / W+ workflow library (Table 3), and the
data-scale binding enumerators (DESIGN.md §12.1)."""
from repro.workloads.enumerators import (build_enumerated_workload,
                                         enumerate_csv, enumerate_sql,
                                         enumerate_table)
from repro.workloads.library import (MIXED_PARTS, WORKFLOWS, build_graph,
                                     build_mixed_workload, build_workload)
from repro.workloads.minidb import MiniDB
from repro.workloads.tools import ToolRuntime

__all__ = ["MIXED_PARTS", "WORKFLOWS", "build_graph",
           "build_mixed_workload", "build_workload",
           "build_enumerated_workload", "enumerate_csv", "enumerate_sql",
           "enumerate_table", "MiniDB", "ToolRuntime"]
