"""Tool operators: SQL (minidb), HTTP (simulated external API), pyfn.

Each execution returns a string (what gets interpolated into downstream
prompts) and reports its wall time to the OperatorProfiler.  HTTP
latency is deterministic per-URL (hash-derived) so runs are reproducible
and stragglers are stable; ``latency_scale`` lets tests run at 0 cost.
"""
from __future__ import annotations

import hashlib
import time
from typing import Callable, Dict, Optional, Tuple

from repro.workloads.minidb import MiniDB


def _http_latency(url: str) -> float:
    h = int.from_bytes(hashlib.blake2b(url.encode(), digest_size=4).digest(),
                       "little")
    return 0.02 + (h % 1000) / 1000.0 * 0.08       # 20–100 ms, deterministic


class ToolRuntime:
    """Executes tool-node ops against the backing database / fake net."""

    def __init__(self, db: MiniDB, latency_scale: float = 1.0,
                 functions: Optional[Dict[str, Callable[[str], str]]] = None):
        self.db = db
        self.latency_scale = latency_scale
        self.functions = dict(functions or {})
        self.functions.setdefault("wordcount", lambda s: str(len(s.split())))
        self.functions.setdefault("upper", lambda s: s.upper())
        # stats
        self.calls: Dict[str, int] = {"sql": 0, "http": 0, "pyfn": 0}
        self.seconds: Dict[str, float] = {"sql": 0.0, "http": 0.0, "pyfn": 0.0}

    # ------------------------------------------------------------------
    def execute(self, op: str, args: str) -> Tuple[str, float]:
        """Run one tool op. Returns (result string, wall seconds)."""
        t0 = time.perf_counter()
        if op == "sql":
            rows = self.db.execute(args)
            result = "; ".join(",".join(str(c) for c in r) for r in rows[:50])
            result = result or "(no rows)"
        elif op == "http":
            lat = _http_latency(args) * self.latency_scale
            if lat > 0:
                time.sleep(lat)
            body = hashlib.blake2b(args.encode(), digest_size=6).hexdigest()
            result = f"http:{body}"
        elif op == "pyfn":
            name, _, arg = args.partition("(")
            arg = arg.rstrip(")")
            fn = self.functions.get(name.strip())
            result = fn(arg) if fn else f"(unknown fn {name!r})"
        else:
            raise ValueError(f"unknown tool op {op!r}")
        dt = time.perf_counter() - t0
        self.calls[op] = self.calls.get(op, 0) + 1
        self.seconds[op] = self.seconds.get(op, 0.0) + dt
        return result, dt

    # ------------------------------------------------------------------
    def explain_hook(self) -> Callable[[str], float]:
        return self.db.explain
