"""Binding enumerators — thousands-of-query batches from DATA
(DESIGN.md §12.1).

The W1–W6/wt/wd samplers draw bindings from small random pools; the
paper's headline workloads bind one query per *row of data* (audit
every page, score every order).  The enumerators here produce those
binding lists from the three data shapes the repo already has:

* ``enumerate_table``  — rows of a ``minidb`` table;
* ``enumerate_sql``    — the result set of any supported SQL query
  (projections, joins, aggregates), so the batch can be "one query per
  group" as easily as "one per row";
* ``enumerate_csv``    — rows of a CSV file on disk.

Each returns the plain ``List[Dict[str, str]]`` the consolidation layer
already takes, so the output feeds ``build_workload`` /
``consolidate_multi`` (and ``ProcessorSession.submit``) unchanged —
enumerated batches dedup, graft, plan and checkpoint exactly like
sampled ones.  ``build_enumerated_workload`` pairs a registered
template with its canonical enumeration (the enumerator → orchestrator
→ worker-pool shape).
"""
from __future__ import annotations

import csv
from typing import Callable, Dict, List, Optional, Sequence

from repro.workloads.minidb import MiniDB, parse_sql

Binding = Dict[str, str]


def _coerce(rows: Sequence[Sequence], names: Sequence[str],
            params: Optional[Dict[str, str]],
            limit: Optional[int]) -> List[Binding]:
    """Rows × column names → binding dicts (values stringified, the
    form ``render()`` interpolates).  ``params`` maps binding key →
    source column; default binds every column under its own name."""
    if params is None:
        params = {c: c for c in names}
    ix: Dict[str, int] = {}
    for key, col in params.items():
        try:
            ix[key] = names.index(col)
        except ValueError:
            raise KeyError(
                f"enumerator param {key!r} wants column {col!r}; "
                f"available columns: {list(names)}") from None
    if limit is not None:
        rows = rows[:limit]
    return [{key: str(r[i]) for key, i in ix.items()} for r in rows]


def enumerate_table(db: MiniDB, table: str,
                    params: Optional[Dict[str, str]] = None,
                    where: Optional[str] = None,
                    limit: Optional[int] = None) -> List[Binding]:
    """One binding per row of ``table`` (insertion order, so the batch
    is deterministic).  ``where`` is an optional SQL predicate pushed
    through the normal query path."""
    t = db.tables[table]
    if where:
        sql = f"SELECT {', '.join(t.columns)} FROM {table} WHERE {where}"
        rows = db.execute(sql)
    else:
        rows = t.rows
    return _coerce(rows, t.columns, params, limit)


def _output_columns(sql: str) -> List[str]:
    """Names of a query's projected columns: bare columns keep their
    name (unqualified), aggregates are ``agg(col)``."""
    names = []
    for agg, col in parse_sql(sql).select:
        col = col.split(".", 1)[1] if "." in col else col
        names.append(f"{agg}({col})" if agg else col)
    return names


def enumerate_sql(db: MiniDB, sql: str,
                  params: Optional[Dict[str, str]] = None,
                  limit: Optional[int] = None) -> List[Binding]:
    """One binding per result row of ``sql`` (any query minidb
    supports).  ``params`` maps binding key → projected column name —
    bare columns by name, aggregates as ``"agg(col)"``."""
    rows = db.execute(sql)
    return _coerce(rows, _output_columns(sql), params, limit)


def enumerate_csv(path: str,
                  params: Optional[Dict[str, str]] = None,
                  limit: Optional[int] = None) -> List[Binding]:
    """One binding per CSV row (header row names the columns)."""
    with open(path, newline="") as f:
        reader = csv.reader(f)
        try:
            header = next(reader)
        except StopIteration:
            raise ValueError(f"CSV {path!r} is empty (no header row)") \
                from None
        rows = list(reader)
    return _coerce(rows, [h.strip() for h in header], params, limit)


# ---------------------------------------------------------------------------
# canonical per-template enumerations
# ---------------------------------------------------------------------------

def _ws_enumeration(db: MiniDB, limit: int) -> List[Binding]:
    # one query per pages row; rank/title distinct per row, topic drawn
    # from the row itself so the per-topic `stats` aggregate coalesces
    return enumerate_sql(
        db, f"SELECT id, title, topic FROM pages ORDER BY id LIMIT {limit}",
        params={"rank": "id", "title": "title", "topic": "topic"})


# workload name -> fn(db, limit) producing its data-derived bindings
ENUMERATIONS: Dict[str, Callable[[MiniDB, int], List[Binding]]] = {
    "ws": _ws_enumeration,
}


def build_enumerated_workload(name: str, limit: int = 2048,
                              db: Optional[MiniDB] = None,
                              paper_scale_estimates: bool = True):
    """A data-scale batch: (GraphSpec, bindings, database name, MiniDB).

    Like ``build_workload`` but the bindings are ENUMERATED from the
    workload's own database rather than sampled — one query per row the
    registered enumeration yields, capped at ``limit``.  The populated
    ``MiniDB`` is returned too so the caller's ``ToolRuntime`` queries
    the same instance the bindings came from.
    """
    from repro.workloads.datagen import build_database
    from repro.workloads.library import build_graph
    if name not in ENUMERATIONS:
        raise KeyError(f"no enumeration registered for workload {name!r} "
                       f"(have: {sorted(ENUMERATIONS)})")
    graph, dbname = build_graph(
        name, paper_scale_estimates=paper_scale_estimates)
    if db is None:
        db = build_database(dbname)
    bindings = ENUMERATIONS[name](db, limit)
    return graph, bindings, dbname, db
