"""Debug-mode lock-order verifier (DESIGN.md §11).

``named_lock(name)`` / ``named_condition(name)`` are drop-in factories
for ``threading.Lock()`` / ``threading.Condition()`` used at every lock
creation site in the threaded runtime.  The ``name`` is the lock's
canonical identity, ``ClassName.attr`` — the same identity the static
lock-discipline checker (``tools/analysis``) uses for its acquisition-
order graph, and the checker verifies the string matches the attribute
the lock is assigned to, so the static and runtime views can never
drift apart.

With ``REPRO_DEBUG_SYNC`` unset (the default, and production) the
factories return plain ``threading`` primitives: zero overhead, zero
behavior change.  With ``REPRO_DEBUG_SYNC=1`` they return checking
wrappers that record, per thread, the stack of currently-held named
locks and, globally, every observed happens-before edge A→B ("B was
acquired while A was held") with a witness traceback.  An acquisition
that would close a cycle in that edge set — i.e. some thread
previously acquired these locks in the opposite order — raises
``LockOrderError`` immediately, with both witness stacks, instead of
leaving a latent deadlock to strike under production timing.  The
nightly CI job runs the full test suite with the verifier on.

Conditions are built over a checking proxy around an ``RLock`` so that
``Condition.wait()``'s release/re-acquire cycle is tracked correctly
(the held-stack entry is popped for the duration of the wait and
re-pushed on wakeup, without re-recording edges that were already
proven).
"""
from __future__ import annotations

import os
import threading
import traceback


def enabled() -> bool:
    """True when the runtime lock-order verifier is switched on."""
    return os.environ.get("REPRO_DEBUG_SYNC", "") == "1"


class LockOrderError(AssertionError):
    """Two threads acquired the same pair of locks in opposite orders."""


def _here() -> str:
    # drop the last two frames (this helper + the registry method)
    return "".join(traceback.format_stack(limit=8)[:-2])


class _OrderRegistry:
    """Global happens-before edges + per-thread held-lock stacks."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._witness: dict = {}       # (a, b) -> stack str proving a→b
        self._succ: dict = {}          # a -> set of b with edge a→b
        self._tls = threading.local()

    # -- per-thread held stack -------------------------------------
    def _stack(self) -> list:
        st = getattr(self._tls, "held", None)
        if st is None:
            st = self._tls.held = []
        return st

    def held(self) -> list:
        """Names currently held by this thread (outermost first)."""
        return list(self._stack())

    def push(self, name: str) -> None:
        self._stack().append(name)

    def push_many(self, name: str, n: int) -> None:
        self._stack().extend([name] * n)

    def pop(self, name: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == name:
                del st[i]
                return

    def pop_all(self, name: str) -> int:
        st = self._stack()
        n = st.count(name)
        if n:
            self._tls.held = [h for h in st if h != name]
        return n

    # -- edge recording / cycle detection --------------------------
    def _reaches(self, src: str, dst: str) -> bool:
        seen, frontier = set(), [src]
        while frontier:
            node = frontier.pop()
            if node == dst:
                return True
            if node in seen:
                continue
            seen.add(node)
            frontier.extend(self._succ.get(node, ()))
        return False

    def check_acquire(self, name: str) -> None:
        """Validate + record edges held→name.  Call BEFORE acquiring."""
        held = self._stack()
        if name in held:               # re-entrant (Condition's RLock)
            return
        outer = set(held)
        if not outer:
            return
        with self._mu:
            for a in outer:
                if name in self._succ.get(a, ()):
                    continue           # edge already proven
                if self._reaches(name, a):
                    prior = self._witness.get((name, a))
                    path = "" if prior is None else (
                        f"\n--- prior witness for {name!r}"
                        f" before {a!r} ---\n{prior}")
                    raise LockOrderError(
                        f"lock-order inversion: acquiring {name!r} while "
                        f"holding {a!r}, but the reverse order was "
                        f"observed earlier (edge set now cyclic).\n"
                        f"--- this acquisition ---\n{_here()}{path}")
                self._succ.setdefault(a, set()).add(name)
                self._witness[(a, name)] = _here()

    def snapshot_edges(self) -> set:
        with self._mu:
            return {(a, b) for a, bs in self._succ.items() for b in bs}


_REGISTRY = _OrderRegistry()


def registry() -> _OrderRegistry:
    """The process-wide order registry (for tests/diagnostics)."""
    return _REGISTRY


class _CheckedLock:
    """Order-checking proxy over a ``threading`` lock primitive.

    Also implements the private Condition plumbing (``_is_owned`` /
    ``_release_save`` / ``_acquire_restore``) by delegating to the
    inner primitive while keeping the held-stack honest across
    ``Condition.wait()``.
    """

    def __init__(self, name: str, inner) -> None:
        self.name = name
        self._inner = inner

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        _REGISTRY.check_acquire(self.name)
        got = self._inner.acquire(blocking, timeout)
        if got:
            _REGISTRY.push(self.name)
        return got

    def release(self) -> None:
        self._inner.release()
        _REGISTRY.pop(self.name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __repr__(self) -> str:
        return f"<_CheckedLock {self.name!r} over {self._inner!r}>"

    # -- Condition support (inner must be an RLock) ----------------
    def _is_owned(self):
        return self._inner._is_owned()

    def _release_save(self):
        n = _REGISTRY.pop_all(self.name)
        return (self._inner._release_save(), n)

    def _acquire_restore(self, saved) -> None:
        state, n = saved
        self._inner._acquire_restore(state)
        _REGISTRY.push_many(self.name, n)


def named_lock(name: str):
    """A ``threading.Lock`` whose canonical name is ``Class.attr``."""
    if not enabled():
        return threading.Lock()
    return _CheckedLock(name, threading.Lock())


def named_condition(name: str):
    """A ``threading.Condition`` whose lock carries ``Class.attr``."""
    if not enabled():
        return threading.Condition()
    return threading.Condition(_CheckedLock(name, threading.RLock()))
