"""Distributed training checkpoints: npz shards + manifest, atomic
commit, ELASTIC RESHARDING on load.

Layout:
    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, mesh info
        shard_<host>.npz     # this host's param/opt leaves (host-local)
        COMMITTED            # written last — partial checkpoints are
                             # never visible to readers (atomic rename)

Elastic resharding: arrays are saved UNSHARDED per leaf (host 0 owns the
gather in this single-process container; on a real fleet each host saves
its addressable shards and the loader reassembles).  On load, leaves are
placed with the CURRENT mesh's NamedSharding — a checkpoint saved on
mesh A restores onto mesh B (elastic scaling / failure recovery).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, params: Any, opt_state: Any,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Atomically write a checkpoint. Returns the committed directory."""
    final_dir = os.path.join(path, f"step_{step:09d}")
    parent = os.path.dirname(final_dir) or "."
    os.makedirs(parent, exist_ok=True)
    tmp_dir = tempfile.mkdtemp(dir=parent, prefix=".ckpt_tmp_")
    try:
        state = {"params": params, "opt": opt_state}
        leaves, treedef = _flatten(state)
        arrays = {}
        for i, x in enumerate(leaves):
            a = np.asarray(x)
            if a.dtype.kind not in "fiub":      # bf16 etc: npz-safe as f32
                a = np.asarray(jnp.asarray(x, jnp.float32))
            arrays[f"leaf_{i}"] = a
        np.savez(os.path.join(tmp_dir, "shard_0.npz"), **arrays)
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "extra": extra or {},
        }
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
            f.write("ok")
        if os.path.exists(final_dir):
            shutil.rmtree(final_dir)
        os.replace(tmp_dir, final_dir)            # atomic commit
        return final_dir
    except BaseException:
        shutil.rmtree(tmp_dir, ignore_errors=True)
        raise


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_")
        and os.path.exists(os.path.join(path, d, "COMMITTED")))
    return os.path.join(path, steps[-1]) if steps else None


def load_checkpoint(ckpt_dir: str, like: Tuple[Any, Any],
                    shardings: Optional[Any] = None
                    ) -> Tuple[int, Any, Any, Dict[str, Any]]:
    """Load (step, params, opt_state, extra), resharding onto ``shardings``
    (a pytree of NamedSharding matching ``like``) if given — this is the
    elastic-rescale path: the saved mesh layout is irrelevant."""
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(ckpt_dir, "shard_0.npz"))
    leaves = [data[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    _, treedef = _flatten({"params": like[0], "opt": like[1]})
    state = jax.tree.unflatten(treedef, leaves)

    def place(x, ref, sh):
        arr = jnp.asarray(x, dtype=ref.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        return arr

    ref_state = {"params": like[0], "opt": like[1]}
    if shardings is not None:
        sh_state = {"params": shardings[0], "opt": shardings[1]}
        state = jax.tree.map(place, state, ref_state, sh_state)
    else:
        state = jax.tree.map(lambda x, r: jnp.asarray(x, r.dtype),
                             state, ref_state)
    return (manifest["step"], state["params"], state["opt"],
            manifest.get("extra", {}))
