"""Train-step factory + loop.

``make_train_step`` builds a jit-able (params, opt, batch) → (params,
opt, metrics) function with optional remat and gradient accumulation
(microbatch scan — the standard memory/compute trade for the train_4k
shapes).  The same factory lowers under pjit for the dry-run meshes
(launch/dryrun.py supplies in_shardings / out_shardings).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.engine.models import build_model
from repro.training.checkpoint import (latest_checkpoint, load_checkpoint,
                                       save_checkpoint)
from repro.training.data import DataConfig, SyntheticLMData
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainerConfig:
    remat: bool = True
    grad_accum: int = 1             # microbatches per step
    adamw: AdamWConfig = AdamWConfig()


def make_train_step(cfg: ModelConfig, tcfg: TrainerConfig
                    ) -> Callable[[Any, Any, Dict[str, jax.Array]],
                                  Tuple[Any, Any, Dict[str, jax.Array]]]:
    model = build_model(cfg)

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, remat=tcfg.remat)

    def train_step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            # split the global batch into microbatches and scan
            def micro(b):
                return jax.tree.map(
                    lambda x: x.reshape((tcfg.grad_accum,
                                         x.shape[0] // tcfg.grad_accum)
                                        + x.shape[1:]), b)

            def acc_body(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc_loss, acc_g = carry
                return (acc_loss + loss,
                        jax.tree.map(jnp.add, acc_g, grads)), None

            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0.0), zeros), micro(batch))
            loss = loss_sum / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = adamw_update(tcfg.adamw, params, grads,
                                             opt_state)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, tcfg: TrainerConfig, data_cfg: DataConfig,
               num_steps: int, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50, log_every: int = 10,
               seed: int = 0, resume: bool = True) -> Dict[str, Any]:
    """Single-host training loop with checkpoint/restart."""
    model = build_model(cfg)
    data = SyntheticLMData(data_cfg)
    step0 = 0
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    if ckpt_dir and resume:
        latest = latest_checkpoint(ckpt_dir)
        if latest:
            step0, params, opt_state, _ = load_checkpoint(
                latest, (params, opt_state))

    # jit-ok: the step closure bakes cfg/tcfg in; batches are fixed-shape
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    t0 = time.perf_counter()
    for step in range(step0, num_steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, params, opt_state)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, num_steps, params, opt_state)
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "seconds": time.perf_counter() - t0}
