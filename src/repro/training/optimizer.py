"""AdamW + cosine schedule — pure-jax pytree optimizer.

State mirrors the parameter pytree (same sharding under pjit: the
optimizer state inherits each parameter's NamedSharding, so FSDP'd
params get FSDP'd moments for free).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: Dict[str, Any]) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step with global-norm clipping. Returns
    (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g32
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (delta + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        p2, m2, v2 = upd(p, g, m, v)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params, new_state, metrics
