"""Training substrate: optimizer, trainer, distributed checkpointing,
gradient compression, resumable data pipeline."""
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.training.trainer import TrainerConfig, make_train_step, train_loop
from repro.training.checkpoint import save_checkpoint, load_checkpoint
from repro.training.data import DataConfig, SyntheticLMData

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
    "TrainerConfig", "make_train_step", "train_loop",
    "save_checkpoint", "load_checkpoint", "DataConfig", "SyntheticLMData",
]
