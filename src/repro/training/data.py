"""Resumable deterministic LM data pipeline.

Batches are a pure function of (seed, step): restart at step k
reproduces exactly the stream a continuous run would have seen — no
iterator state to checkpoint, and elastic rescaling (different host
counts re-sharding the same global batch) is trivially consistent.
Straggler mitigation hook: ``skip_ahead`` lets a restarted/lagging host
jump to the current global step without replaying.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # lightweight structure so the loss actually falls during smoke
    # training: tokens follow a noisy arithmetic progression
    structure: float = 0.8


class SyntheticLMData:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, host_id: int = 0,
                 num_hosts: int = 1) -> Dict[str, np.ndarray]:
        """The (host-sharded) batch for one global step — pure function."""
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        per_host = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id]))
        base = rng.integers(0, cfg.vocab_size,
                            size=(per_host, 1), dtype=np.int32)
        stride = rng.integers(1, 7, size=(per_host, 1), dtype=np.int32)
        pos = np.arange(cfg.seq_len, dtype=np.int32)[None, :]
        seq = (base + stride * pos) % cfg.vocab_size
        noise_mask = rng.random((per_host, cfg.seq_len)) > cfg.structure
        noise = rng.integers(0, cfg.vocab_size,
                             size=(per_host, cfg.seq_len), dtype=np.int32)
        tokens = np.where(noise_mask, noise, seq).astype(np.int32)
        return {"tokens": tokens, "labels": tokens}

    def stream(self, start_step: int = 0, host_id: int = 0,
               num_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step, host_id, num_hosts)
            step += 1

    def skip_ahead(self, current_step: int) -> int:
        """Straggler mitigation: resume from the fleet's current step."""
        return current_step
