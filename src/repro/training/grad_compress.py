"""Int8 gradient compression with error feedback — a distributed-
optimization hook for cross-pod (DCN) gradient reduction.

Cross-pod all-reduce is the bandwidth bottleneck at 2+ pods (25 GB/s DCN
vs 50 GB/s/link ICI): int8 quantization cuts that traffic 2× vs bf16
(4× vs f32) at the cost of quantization noise, which ERROR FEEDBACK
re-injects next step (residual accumulation keeps the scheme unbiased
in the long run — Seide et al.; Karimireddy et al.).

Usage inside a shard_map'd train step (see distribution/collectives.py
for the psum wiring):

    q, scale, new_err = quantize_error_feedback(g, err)
    q_sum  = lax.psum(q.astype(jnp.int32), "pod")     # int32 accumulate
    g_next = dequantize(q_sum, lax.pmax(scale, "pod"))
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def quantize_error_feedback(g: jax.Array, err: jax.Array
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + carried error); the new residual feeds the next step."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize(corrected)
    new_err = corrected - dequantize(q, scale)
    return q, scale, new_err


def init_error_state(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_tree(grads: Any, err_state: Any):
    """Tree-wise quantize with error feedback.

    Returns (q_tree int8, scale_tree, new_err_state).  The caller reduces
    q_tree across the slow axis and dequantizes (see collectives)."""
    qs, scales, errs = [], [], []
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize_error_feedback(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def decompress_tree(q_tree: Any, scale_tree: Any) -> Any:
    return jax.tree.map(dequantize, q_tree, scale_tree)
