"""Serving launcher: Halo end-to-end over a workload.

    python -m repro.launch.serve --workload w1 --queries 64 --mode sim
    python -m repro.launch.serve --workload w1 --queries 4  --mode real

``sim`` reproduces paper-scale behaviour via the discrete-event backend;
``real`` executes tiny JAX models + minidb and verifies semantics.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_smoke
from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate)
from repro.runtime import RealProcessor, SimulatedProcessor
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime


def build_cost_model(graph, cons, hardware="h200", **kw):
    batch_sizes = {}
    for nid in graph.nodes:
        m = cons.macro(nid)
        batch_sizes[nid] = (m.n_logical if graph.nodes[nid].is_llm()
                            else m.n_unique)
    return CostModel(graph, HARDWARE[hardware], PAPER_MODELS,
                     batch_sizes=batch_sizes, **kw)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="w1")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--mode", choices=("sim", "real"), default="sim")
    ap.add_argument("--hardware", default="h200")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    graph, bindings, dbname = build_workload(args.workload, args.queries,
                                             seed=args.seed)
    cons = consolidate(graph, bindings)
    cm = build_cost_model(graph, cons, args.hardware)
    plan = EpochDPSolver(graph.llm_dag(), cm,
                         SolverConfig(num_workers=args.workers)).solve()
    print(f"plan: {len(plan.epochs)} epochs, predicted {plan.predicted_cost:.2f}s,"
          f" solver {plan.solver_seconds*1e3:.1f}ms")

    if args.mode == "sim":
        rep = SimulatedProcessor(graph, cm, args.workers).run(cons, plan)
    else:
        if args.queries > 8:
            print(f"[real mode] capping --queries {args.queries} -> 8 "
                  "(CPU real-execution scale)")
            cons = consolidate(graph, bindings[:8])
        db = build_database(dbname)
        models = {m: get_smoke("qwen3-1.7b").replace(name=m)
                  for m in ("qwen3-14b", "qwen3-32b", "gpt-oss-20b")}
        proc = RealProcessor(graph, models, ToolRuntime(db),
                             num_workers=min(args.workers, 2), decode_cap=8)
        rep = proc.run(cons, plan)
        rep.extra.pop("results", None)
    print(json.dumps(rep.summary(), indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
