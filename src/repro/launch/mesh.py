"""Production meshes.

Single pod:  (16, 16) over ("data", "model")   = 256 chips (TPU v5e pod)
Multi-pod :  (2, 16, 16) over ("pod", "data", "model") = 512 chips.

The "pod" axis composes with "data" for batch/FSDP sharding so only
gradient/weight-gather traffic crosses the (slower) DCN between pods;
all TP collectives stay on intra-pod ICI.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on newer
    jax; older versions create Auto-typed meshes by default, so omitting
    the argument is behaviour-preserving.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


_make = compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_debug_mesh(num_devices: int = 8):
    """Small mesh over however many (host) devices exist — for tests."""
    n = min(num_devices, len(jax.devices()))
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return _make((n // model, model), ("data", "model"))
