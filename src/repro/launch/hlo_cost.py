"""HLO-text cost analyzer with correct while-loop accounting.

``compiled.cost_analysis()`` counts a while (scan) body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes by ~L×.  This analyzer
re-derives the three roofline inputs from the partitioned HLO text:

* FLOPs: 2·(result elements)·(contraction size) per dot (incl. dots in
  fused computations), multiplied through ``known_trip_count`` of every
  enclosing while;
* HBM bytes: Σ over scheduled top-level ops of operand+result bytes
  (fusion boundaries = kernel boundaries, which is exactly the fused-
  kernel traffic model), same trip multiplication;
* collective bytes: per-op RESULT payloads of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, trip-multiplied.

Everything is per-DEVICE (the HLO is the single-partition SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
# result shapes then the first `kind(` token (shape text never has word-parens)
_OP_RE = re.compile(r"^(.*?)\s([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r"known_trip_count.*?\"n\":\"(\d+)\"")
_CALL_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_REF_RE = re.compile(r"%([\w.\-]+)")
_KIND_PAREN_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _operand_refs(rhs: str) -> List[str]:
    """Operand names of an op definition's right-hand side.

    Handles both the legacy bare syntax ``dot(%a, %b)`` and the typed
    syntax newer jax versions print, ``dot(f32[2,3]{1,0} %a, ...)``.  The
    operand list is the first parenthesized group following the op kind;
    scanning stops at its matching close paren so trailing attributes
    (``body=%c``, metadata) are never picked up.  Tuple-typed operands may
    nest parens, hence the depth tracking.
    """
    m = _KIND_PAREN_RE.search(rhs)
    if not m:
        return []
    start = m.end() - 1                    # index of the opening paren
    depth = 0
    for i in range(start, len(rhs)):
        ch = rhs[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _REF_RE.findall(rhs[start:i])
    return _REF_RE.findall(rhs[start:])
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota"}


def _shapes_bytes_elems(text: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for m in _SHAPE_RE.finditer(text):
        e = 1
        for d in m.group(2).split(","):
            if d:
                e *= int(d)
        total_e += e
        total_b += e * _DTYPE_BYTES.get(m.group(1), 4)
    return total_b, total_e


@dataclass
class _Op:
    name: str
    kind: str
    shape_text: str          # result shapes (lhs)
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    vmem_class_bytes: float = 0.0      # attention-score traffic a flash
                                       # kernel keeps in VMEM (never HBM)
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.vmem_class_bytes += other.vmem_class_bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_count += other.coll_count * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult


class HloAnalyzer:
    def __init__(self, hlo_text: str, score_dims=()):
        """``score_dims``: KV-sequence lengths; f32 tensors of rank ≥ 4
        whose last dim matches are attention scores — the Pallas flash
        kernels keep those in VMEM, so their traffic is tracked separately
        (vmem_class_bytes) and excluded from the kernelized HBM total."""
        self.comps: Dict[str, List[_Op]] = {}
        self.shapes: Dict[str, str] = {}       # op name -> result shape text
        self.entry: Optional[str] = None
        self.score_dims = set(int(d) for d in score_dims)
        self._memo: Dict[str, Cost] = {}
        # dtype-convert fusions are CPU-backend artifacts (TPU matmuls are
        # native bf16): treat them as aliases of their source operand
        self.alias: Dict[str, str] = {}
        self._parse(hlo_text)

    # ------------------------------------------------------------------
    def _is_score_shape(self, shape_text: str) -> bool:
        if not self.score_dims:
            return False
        m = _SHAPE_RE.search(shape_text)
        if not m or m.group(1) not in ("f32", "bf16"):
            return False
        dims = [int(d) for d in m.group(2).split(",") if d]
        if len(dims) < 4:
            return False
        # scores appear as (..., bq, Skv'), transposed (..., Skv', bq·G),
        # with Skv' either the full KV length or a causal-truncated chunk
        # (multiple of 1024 up to the max KV length).  f32 rank-4+ only —
        # bf16 rank-4 tensors (KV, MoE buffers) need the exact length.
        smax = max(self.score_dims)
        cand = max(dims[-1], dims[-2])
        if m.group(1) == "f32":
            return cand >= 1024 and cand <= smax and cand % 1024 == 0
        return dims[-1] in self.score_dims or dims[-2] in self.score_dims

    # ------------------------------------------------------------------
    @staticmethod
    def _merge_wrapped(text: str) -> List[str]:
        """HLO pretty-printing wraps long op lines (tuple results, operand
        lists); merge continuations back into one logical line."""
        starter = re.compile(
            r"^\s*(ENTRY\s+)?(ROOT\s+)?%[\w.\-]+\s*(=|\()|^\s*\}|^HloModule")
        out: List[str] = []
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line.strip():
                continue
            if starter.match(line) or not out:
                out.append(line)
            else:
                out[-1] += " " + line.strip()
        return out

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for line in self._merge_wrapped(text):
            hdr = _COMP_HDR.match(line.strip())
            if hdr and line.strip().endswith("{"):
                current = hdr.group(2)
                self.comps[current] = []
                if hdr.group(1):
                    self.entry = current
                continue
            if line.strip() == "}":
                current = None
                continue
            if current is None:
                continue
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), _COMMENT_RE.sub("", m.group(2))
            om = _OP_RE.match(rhs)
            if om:
                shape_text, kind = om.group(1), om.group(2)
            else:
                # e.g. "%x = f32[2]{0} parameter(0)" matched above; or consts
                parts = rhs.split()
                shape_text = parts[0]
                kind = parts[1].split("(")[0] if len(parts) > 1 else "?"
            self.shapes[name] = shape_text
            if (kind == "convert"
                    or (kind == "fusion" and name.split(".")[0] in (
                        "convert_bitcast_fusion", "convert_fusion",
                        "bitcast_convert_fusion", "wrapped_convert"))):
                refs = _operand_refs(rhs)
                if refs:
                    src = refs[0]
                    # alias only a pure dtype cast (same element count);
                    # fused slice+convert reads just the slice instead
                    src_shape = self.shapes.get(src, "")
                    if src_shape and (_shapes_bytes_elems(src_shape)[1]
                                      == _shapes_bytes_elems(shape_text)[1]):
                        self.alias[name] = src
                    else:
                        self.alias[name] = f"__slice__{name}"
                        self.shapes[f"__slice__{name}"] = shape_text
            self.comps[current].append(_Op(name, kind, shape_text, line))

    # ------------------------------------------------------------------
    def _operand_byte_list(self, line: str) -> Tuple[List[int], int]:
        """(per-operand hbm byte list, score-class bytes)."""
        out: List[int] = []
        score = 0
        for ref in _operand_refs(line):
            for _ in range(8):                  # resolve convert aliases
                if ref in self.alias:
                    ref = self.alias[ref]
                else:
                    break
            st = self.shapes.get(ref)
            if st:
                b = _shapes_bytes_elems(st)[0]
                if self._is_score_shape(st):
                    score += b
                else:
                    out.append(b)
        return out, score

    def _operand_bytes(self, line: str) -> Tuple[int, int]:
        """(hbm bytes, score-class bytes) read by this op's operands."""
        lst, score = self._operand_byte_list(line)
        return sum(lst), score

    def _dot_flops(self, op: _Op) -> float:
        result_b, result_e = _shapes_bytes_elems(op.shape_text)
        cm = _LHS_CONTRACT.search(op.line)
        refs = _operand_refs(op.line)
        if not refs:
            return 0.0
        lhs = refs[0]
        lhs_shape = self.shapes.get(lhs, "")
        sm = _SHAPE_RE.search(lhs_shape)
        if not sm:
            return 0.0
        dims = [int(d) for d in sm.group(2).split(",") if d]
        k = 1
        if cm:
            for ix in cm.group(1).split(","):
                if ix and int(ix) < len(dims):
                    k *= dims[int(ix)]
        return 2.0 * result_e * k

    # ------------------------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        hit = self._memo.get(comp)
        if hit is not None:
            return hit
        self._memo[comp] = Cost()            # cycle guard
        total = Cost()
        for op in self.comps.get(comp, []):
            if op.kind in _FREE_OPS:
                continue
            if op.kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                bm = _CALL_RE.search(op.line)
                if bm:
                    total.add(self.cost_of(bm.group(1)), trip)
                continue
            rb, re_ = _shapes_bytes_elems(op.shape_text)
            score_result = self._is_score_shape(op.shape_text)
            ob_list, ob_score = self._operand_byte_list(op.line)
            ob = sum(ob_list)

            # in-place updates: TPU aliases the big buffer; real traffic is
            # the written slice (≈ the non-aliased operands), not the full
            # cache/stacked-KV tensor the HLO text nominally rewrites.
            if ("dynamic-update-slice" in op.kind
                    or ("dynamic-update-slice" in op.name)
                    or ("dynamic_update_slice" in op.name)):
                slice_b = ob - (max(ob_list) if ob_list else 0)
                total.bytes += 2 * slice_b
                total.vmem_class_bytes += ob_score
                continue
            # loop-carry copies >64 MiB: buffer-aliasing artifacts of the
            # CPU backend (elided by TPU buffer assignment)
            if op.kind == "copy" and rb > 64 * 1024 * 1024 \
                    and len(ob_list) == 1 and ob_list[0] == rb:
                continue
            # dtype-convert aliases: no HBM traffic on TPU
            if op.name in self.alias:
                continue
            # slice-class reads touch only the slice, not the source buffer
            # (scanning a stacked cache dynamic-slices one layer per step)
            if (op.kind in ("dynamic-slice", "gather", "slice")
                    or "dynamic-slice" in op.name
                    or "dynamic_slice" in op.name
                    or op.name.startswith(("gather", "wrapped_gather",
                                           "slice", "wrapped_slice"))):
                total.bytes += 2 * rb
                total.vmem_class_bytes += ob_score
                continue
            # scatter writes only its updates (in-place on TPU)
            if op.kind == "scatter" or "scatter" in op.name:
                slice_b = ob - (max(ob_list) if ob_list else 0)
                total.bytes += 2 * max(slice_b, rb // 64)
                total.vmem_class_bytes += ob_score
                continue

            def account():
                if score_result:
                    total.vmem_class_bytes += rb + ob_score
                    total.bytes += ob
                else:
                    total.bytes += rb + ob
                    total.vmem_class_bytes += ob_score

            if op.kind in ("conditional", "call", "fusion", "map",
                           "reduce", "reduce-window", "sort", "scatter",
                           "select-and-scatter"):
                account()                        # kernel-boundary traffic
                # dots nested inside the called computation still count
                cm = _CALL_RE.search(op.line)
                if cm and cm.group(1) in self.comps:
                    inner = self.cost_of(cm.group(1))
                    total.flops += inner.flops
                    total.coll_bytes += inner.coll_bytes
                continue
            if op.kind in ("dot",):
                total.flops += self._dot_flops(op)
                account()
            elif op.kind == "convolution":
                # approx: 2 * result * (kernel elems) — rare in this repo
                total.flops += 2.0 * re_
                account()
            elif any(op.kind.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.kind.startswith(c))
                if op.kind.endswith("-done"):
                    continue                    # counted at -start
                total.coll_bytes += rb
                total.coll_count += 1
                total.coll_by_kind[kind] = \
                    total.coll_by_kind.get(kind, 0.0) + rb
                account()
            else:
                account()
        self._memo[comp] = total
        return total

    def analyze(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_hlo(hlo_text: str, score_dims=()) -> Dict[str, float]:
    c = HloAnalyzer(hlo_text, score_dims=score_dims).analyze()
    out = {
        "flops": c.flops,
        "bytes": c.bytes,                      # kernelized HBM traffic
        "vmem_class_bytes": c.vmem_class_bytes,
        "bytes_xla_path": c.bytes + c.vmem_class_bytes,
        "collective_bytes": c.coll_bytes,
        "collective_count": c.coll_count,
    }
    for k, v in c.coll_by_kind.items():
        out[f"coll_{k}"] = v
    return out
