"""Roofline analysis over the dry-run artifacts (§Roofline).

Per (arch × shape) cell on the single-pod mesh:

    compute term    = HLO_FLOPs   / (chips × 197e12 FLOP/s)
    memory term     = HLO_bytes   / (chips × 819e9 B/s)
    collective term = coll_bytes  / (chips × 50e9 B/s per link)

HLO_FLOPs / bytes / collective bytes come from the while-trip-corrected
HLO analyzer (launch/hlo_cost.py) and are PER-DEVICE, so the "chips ×"
denominators cancel to per-chip peaks.  The dominant term is the
bottleneck; MODEL_FLOPS/HLO_FLOPs exposes remat & redundancy waste.

    python -m repro.launch.roofline [--json] [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.configs.base import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# kernel-level speed-of-light (benchmarks/kernel_bench.py scores against
# these; same per-chip peaks as the dry-run roofline above)
# ---------------------------------------------------------------------------

def kernel_time_bound(bytes_hbm: float, flops: float, *,
                      hbm_bw: float = HBM_BW,
                      peak_flops: float = PEAK_FLOPS_BF16) -> float:
    """Speed-of-light seconds for ONE kernel dispatch: the slower of the
    memory term (every HBM byte once at peak bandwidth) and the compute
    term (every FLOP at peak throughput).  Decode attention sits deep in
    the memory regime, so this is in effect ``bytes / HBM_BW``."""
    return max(bytes_hbm / hbm_bw, flops / peak_flops)


def pct_of_roofline(measured_s: float, bytes_hbm: float, flops: float, *,
                    hbm_bw: float = HBM_BW,
                    peak_flops: float = PEAK_FLOPS_BF16) -> float:
    """Achieved fraction of the kernel speed-of-light, in percent
    (100 = the dispatch ran exactly at the roofline bound)."""
    bound = kernel_time_bound(bytes_hbm, flops, hbm_bw=hbm_bw,
                              peak_flops=peak_flops)
    return 100.0 * bound / max(measured_s, 1e-30)


def paged_decode_cost(B: int, H: int, Hkv: int, Dh: int, page_size: int,
                      n_pages: int, *, dtype_bytes: int = 4,
                      fused: bool = False, lengths=None):
    """(HBM bytes, FLOPs) model for one paged-decode attention dispatch.

    Each live page's KV is streamed once (the kernels DMA per-KV-head
    ``(page, Dh)`` slices, so summed over heads a page's bytes are read
    exactly once); q and out are negligible B·H·Dh terms.  ``fused``
    adds the appended token's KV write — and saves the separate scatter
    dispatch's full round-trip, which is NOT in this dispatch's bytes.
    ``lengths`` (default: all rows full) drives the per-row page count,
    mirroring the kernels' early-out.
    """
    if lengths is None:
        lengths = [n_pages * page_size - 1] * B
    live = [ln for ln in lengths if ln >= 0]
    pages = sum(ln // page_size + 1 for ln in live)
    kv_bytes = 2 * pages * page_size * Hkv * Dh * dtype_bytes
    qo_bytes = 2 * B * H * Dh * dtype_bytes
    append_bytes = 2 * B * Hkv * Dh * dtype_bytes if fused else 0
    tokens = sum(ln + 1 for ln in live)
    flops = 4.0 * H * Dh * tokens                  # QK^T + PV per token
    return kv_bytes + qo_bytes + append_bytes, flops


def model_flops(arch: str, shape_name: str) -> float:
    """6·N·D (train) / 2·N_active·D (inference), D = processed tokens."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch                    # one decode step
    return 2.0 * n_active * tokens


def analyze_cell(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    n_dev = cell["devices"]
    flops = cell["flops"]                          # per device
    byts = cell["bytes_accessed"]
    coll = cell["collectives"].get("collective_bytes", 0.0)
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"]) / n_dev
    bound = max(terms.values())
    useful_frac = mf / max(flops, 1.0)
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
        "useful_flop_ratio": useful_frac,
        # roofline fraction: useful work at peak vs the bound the compiled
        # program actually hits
        "roofline_fraction": (mf / PEAK_FLOPS_BF16) / max(bound, 1e-30),
        "hbm_fit": cell.get("hbm_fit"),
    }


def load_cells(dirname: str, mesh: str = "pod16x16") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if c.get("mesh") == mesh:
            cells.append(c)
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=OUT_DIR)
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    rows = []
    skipped = []
    for cell in load_cells(args.dir, args.mesh):
        r = analyze_cell(cell)
        if r is None:
            skipped.append((cell["arch"], cell["shape"],
                            cell.get("reason", cell.get("error", ""))[:60]))
            continue
        rows.append(r)

    if args.json:
        print(json.dumps(rows, indent=1))
        return 0

    hdr = (f"{'arch':20s} {'shape':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s} fit")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"{r['arch']:20s} {r['shape']:12s} "
              f"{r['t_compute_s']:10.4f} {r['t_memory_s']:10.4f} "
              f"{r['t_collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['useful_flop_ratio']:7.2f} "
              f"{100*r['roofline_fraction']:6.1f}% {r['hbm_fit']}")
    if skipped:
        print("\nskipped cells:")
        for a, s, why in skipped:
            print(f"  {a:20s} {s:12s} {why}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
