"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

CPU-runnable with reduced configs (--smoke, the default here) and the
same code path that lowers on the production meshes (launch/dryrun.py
proves every full (arch × train shape) compiles there).
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, get_smoke
from repro.training import (AdamWConfig, DataConfig, TrainerConfig,
                            train_loop)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the assigned full config (TPU-scale; "
                    "default uses the smoke config)")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full_config else get_smoke(args.arch)
    tcfg = TrainerConfig(
        remat=True, grad_accum=args.grad_accum,
        adamw=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch)
    out = train_loop(cfg, tcfg, dcfg, num_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, log_every=max(args.steps//20, 1))
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"\narch={cfg.name} steps={args.steps} "
          f"loss {first:.4f} -> {last:.4f} in {out['seconds']:.1f}s")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
