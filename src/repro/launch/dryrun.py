import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

This proves the distribution config is coherent without real hardware:
sharding mismatches, compile-time OOM and unsupported collectives all
surface here as failures.  Per cell we record:

* per-device memory from ``compiled.memory_analysis()`` (fits 16 GiB?)
* HLO FLOPs / bytes from ``compiled.cost_analysis()``
* collective bytes parsed from the partitioned HLO text
  (all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute result sizes)

Outputs one JSON per cell under experiments/dryrun/ — the roofline
analysis (launch/roofline.py) consumes these.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_applicable)
from repro.configs.base import HBM_BYTES, ModelConfig, ShapeSpec
from repro.launch.hlo_cost import analyze_hlo
from repro.distribution.sharding import (ShardingPolicy, cache_shardings,
                                         input_shardings, param_shardings)
from repro.engine.models import build_model
from repro.launch.mesh import make_production_mesh
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.trainer import TrainerConfig, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(tok_type: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(tok_type, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


_COLL_LINE = re.compile(
    r"=\s+(?P<shapes>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum RESULT sizes of every collective op in the partitioned HLO.

    Result size is the per-device payload a chip receives for that op —
    the bytes that cross its ICI links (methodology note: for
    reduce-scatter the operand is larger than the result; using results
    uniformly makes the ring-traffic estimate consistent across op
    kinds)."""
    out = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _COLL_LINE.search(line)
        if not m:
            continue
        op = m.group("op")
        for sm in _SHAPE_RE.finditer(m.group("shapes")):
            out[op] += _shape_bytes(sm.group(1), sm.group(2))
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
def _activation_residency(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Analytic per-device activation residency (bytes).

    Train: remat (nothing_saveable) keeps one hidden-state carry per
    scanned layer plus one layer's working set (chunked-attention block
    scores, FFN intermediates).  Inference: one layer's working set plus
    (decode) nothing — the cache is in arguments.
    """
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            dp *= mesh.shape[ax]
    tp = mesh.shape.get("model", 1)
    B = max(shape.global_batch // dp, 1)
    S = shape.seq_len if shape.kind != "decode" else 1
    D = cfg.d_model
    L = cfg.num_layers
    hid = B * S * D * 2                              # bf16 hidden state
    ffn = max(cfg.d_ff, cfg.moe.d_ff_expert if cfg.moe else 0)
    work = 3 * hid + 2 * B * S * max(ffn // tp, D) * 2
    if shape.kind != "decode" and S > 1:
        skv = min(S, cfg.swa_window or S)
        bq = min(4 * 1024 * 1024 // max(skv, 1), S) or S
        scores = B * cfg.num_heads * bq * skv * 4
        work += scores
    if shape.kind == "train":
        return int(L * hid + 3 * work)               # fwd+bwd live sets
    return int(2 * work)


def _abstract_params(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def build_lowerable(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (jitted fn, example args as ShapeDtypeStructs)."""
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    pol = ShardingPolicy.for_mesh(mesh, fsdp_params=(shape.kind == "train"))
    # sequence-parallel attention hints (REPRO_SP_ATTENTION=0 disables)
    from repro.engine.models.layers import set_activation_sharding
    set_activation_sharding(mesh, batch_axes=pol.batch_axes)
    params_shape = _abstract_params(model)
    p_sh = param_shardings(params_shape, mesh, pol)
    in_sh = input_shardings(cfg, shape, mesh, pol)

    if shape.kind == "train":
        tcfg = TrainerConfig(remat=True, grad_accum=1,
                             adamw=AdamWConfig(total_steps=1000))
        step = make_train_step(cfg, tcfg)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        o_sh = param_shardings(opt_shape, mesh, pol)
        batch_sh = {k: in_sh[k] for k in specs}
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, batch_sh),
                     out_shardings=(p_sh, o_sh, None))
        return fn, (params_shape, opt_shape, specs)

    if shape.kind == "prefill":
        if cfg.family == "audio":
            def step(p, tokens, frames):
                return model.prefill(p, tokens, frames)
            args = (params_shape, specs["tokens"], specs["frames"])
            arg_sh = (p_sh, in_sh["tokens"], in_sh["frames"])
        elif cfg.family == "vlm":
            def step(p, tokens, patches):
                return model.prefill(p, tokens, prefix_embeds=patches)
            args = (params_shape, specs["tokens"], specs["patch_embeds"])
            arg_sh = (p_sh, in_sh["tokens"], in_sh["patch_embeds"])
        else:
            def step(p, tokens):
                return model.prefill(p, tokens)
            args = (params_shape, specs["tokens"])
            arg_sh = (p_sh, in_sh["tokens"])
        fn = jax.jit(step, in_shardings=arg_sh)
        return fn, args

    # decode: one new token against a seq_len-deep cache (serve_step)
    B = shape.global_batch
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, shape.seq_len))
    c_sh = cache_shardings(cache_shape, cfg, mesh, B,
                           batch_axes_tree=model.cache_batch_axes(cache_shape))

    def serve_step(p, token, cache):
        return model.decode_step(p, token, cache)

    fn = jax.jit(serve_step, in_shardings=(p_sh, in_sh["token"], c_sh),
                 out_shardings=(None, c_sh))
    return fn, (params_shape, specs["token"], cache_shape)


# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        fn, args = build_lowerable(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                # NOTE: CPU backend reports temps WITHOUT buffer-liveness
                # packing — a loose upper bound, kept for reference only.
                "xla_temp_bytes_upper": getattr(mem, "temp_size_in_bytes",
                                                None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
            }
        except Exception:
            mem_stats = {}
        # per-device flops/bytes/collectives with while-trip accounting;
        # attention-score tensors (kept in VMEM by the Pallas kernels on
        # the real deployment) are tracked separately from HBM traffic
        score_dims = {shape.seq_len}
        if cfg.swa_window:
            score_dims.add(min(shape.seq_len, cfg.swa_window))
        if cfg.family == "hybrid":
            score_dims.add(min(shape.seq_len, cfg.local_attn_window))
        hlo = analyze_hlo(compiled.as_text(), score_dims=score_dims)

        n_dev = mesh.devices.size
        arg_b = mem_stats.get("argument_bytes") or 0
        est = arg_b + _activation_residency(cfg, shape, mesh)
        cell.update(
            status="ok",
            devices=n_dev,
            flops=hlo["flops"],
            bytes_accessed=hlo["bytes"],
            collectives={k: v for k, v in hlo.items()
                         if k.startswith("coll")},
            xla_cost_flops=cost.get("flops", 0.0),      # scan-body-once ref
            memory=mem_stats,
            per_device_bytes=est,
            hbm_fit=bool(est <= HBM_BYTES),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
    except Exception as e:                       # failure IS the signal
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(cell, f, indent=1, default=str)
    return cell


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = []
    if args.single_pod or not args.multi_pod:
        pods.append(False)
    if args.multi_pod or args.all:
        pods.append(True)
    if args.all and False in pods and True not in pods:
        pods.append(True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                fname = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    with open(fname) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip-existing] {arch} {shape} {mesh_name}")
                        continue
                cell = run_cell(arch, shape, mp, args.out)
                status = cell["status"]
                extra = ""
                if status == "ok":
                    extra = (f"flops={cell['flops']:.3e} "
                             f"coll={cell['collectives'].get('collective_bytes', 0):.3e}B "
                             f"fit={cell['hbm_fit']} "
                             f"compile={cell['compile_s']}s")
                elif status == "error":
                    extra = cell["error"][:160]
                    failures += 1
                else:
                    extra = cell.get("reason", "")
                print(f"[{status:7s}] {arch:20s} {shape:12s} {mesh_name}  "
                      f"{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
