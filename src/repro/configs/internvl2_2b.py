"""internvl2-2b — VLM: InternLM2 backbone; ViT frontend is a STUB.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
``input_specs`` feeds precomputed patch embeddings (B, 256, d_model)
prepended to the token sequence.  [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1000000.0,
    num_patches=256,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_patches=8,
    )
