"""Configuration system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
config is a frozen dataclass so it can be hashed / used as a jit static
argument, and every field is serializable for checkpoint manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e-class target; see DESIGN.md §2)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, bytes/s
HBM_BYTES = 16 * 1024**3      # per chip
ICI_BW = 50e9                 # per link, bytes/s
DCN_BW = 25e9                 # per host, bytes/s (cross-pod)
HOST_TO_HBM_BW = 32e9         # weight-loading path (PCIe-class), bytes/s


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_dense_layers: int = 0          # leading layers that use a dense FFN
    d_ff_dense: int = 0                  # width of those dense FFNs
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense|moe|audio|vlm|ssm|hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                    # 0 -> d_model // num_heads
    max_seq_len: int = 532480            # generous default; shapes clamp it
    rope_theta: float = 500000.0
    qk_norm: bool = False
    swa_window: int = 0                  # 0 -> full attention
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    # --- encoder/decoder (whisper) ---
    enc_layers: int = 0                  # >0 => encoder-decoder model
    enc_max_len: int = 0
    # --- hybrid / ssm block pattern ---
    # e.g. ("rglru", "rglru", "attn") repeated; ("mlstm", "slstm") repeated
    block_pattern: Tuple[str, ...] = ()
    local_attn_window: int = 2048        # for hybrid local attention blocks
    lru_width: int = 0                   # RG-LRU width (0 -> d_model)
    conv1d_width: int = 4                # temporal conv inside recurrent block
    # --- vlm ---
    num_patches: int = 0                 # prepended patch embeddings (stub frontend)
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # --- attention implementation: "xla" (ref) or "pallas" ---
    attention_impl: str = "xla"
    # Whether the KV/prefix-sharing discount of the Halo cost model may be
    # applied at sub-prefix granularity (False for pure-recurrent archs).
    supports_partial_prefix: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so embedding/lm_head shard evenly on 16-way TP."""
        return round_up(self.vocab_size, 256)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_recurrent(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def full_attention(self) -> bool:
        """True if the arch relies on unbounded dense self-attention."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            return False                  # local attention windows are bounded
        return self.swa_window == 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.resolved_head_dim
        h, hkv = self.num_heads, self.num_kv_heads
        embed = self.padded_vocab * d
        head = 0 if self.tie_embeddings else self.padded_vocab * d

        def attn_params():
            return d * h * dh + 2 * d * hkv * dh + h * dh * d

        def dense_ffn(ff):
            return 3 * d * ff

        total = embed + head + d  # final norm
        pattern = self.block_pattern or ("attn",) * self.num_layers
        for i in range(self.num_layers):
            kind = pattern[i % len(pattern)]
            total += 2 * d  # norms
            if kind == "attn":
                total += attn_params()
                if self.moe is not None:
                    m = self.moe
                    if i < m.first_dense_layers:
                        total += dense_ffn(m.d_ff_dense or self.d_ff)
                    else:
                        total += m.num_experts * 3 * d * m.d_ff_expert
                        total += m.num_shared_experts * 3 * d * m.d_ff_expert
                        total += d * m.num_experts  # router
                elif self.d_ff:
                    total += dense_ffn(self.d_ff)
            elif kind == "rglru":
                w = self.lru_width or d
                # in/out proj + gates + conv
                total += 2 * d * w + 2 * w + self.conv1d_width * w + w * d
                total += dense_ffn(self.d_ff) if self.d_ff else 0
            elif kind in ("mlstm", "slstm"):
                inner = 2 * d
                total += d * inner * 4 + inner * d  # projections + gates (approx)
        if self.is_encdec:
            # encoder blocks + cross attention in decoder
            total += self.enc_layers * (2 * d + attn_params() + dense_ffn(self.d_ff))
            total += self.num_layers * (d + attn_params())
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE activates top_k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        total_experts = self.num_layers - m.first_dense_layers
        inactive = total_experts * (m.num_experts - m.top_k) * 3 * d * m.d_ff_expert
        return int(self.param_count() - inactive)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (all 10 archs share this set)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is runnable; reason if not.

    long_500k requires sub-quadratic attention (bounded window / recurrent
    state); pure full-attention archs skip it (documented in DESIGN.md).
    """
    if shape.name == "long_500k" and cfg.full_attention:
        return False, "full dense attention cannot hold a 512k KV (O(S^2))"
    return True, ""
