"""qwen3-1.7b — dense decoder with qk-norm + GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
[hf:Qwen/Qwen3-1.7B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1000000.0,
    qk_norm=True,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
