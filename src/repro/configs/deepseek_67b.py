"""deepseek-67b — llama-architecture dense decoder.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
[arXiv:2401.02954; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    rope_theta=10000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
