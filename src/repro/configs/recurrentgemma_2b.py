"""recurrentgemma-2b — Griffin: RG-LRU blocks + local attention, 2:1.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
Block pattern (rglru, rglru, attn) repeating; local window 2048 bounds KV,
so long_500k decode is runnable.  [arXiv:2402.19427; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,              # 26 blocks: pattern tiled (rglru,rglru,attn)
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "attn"),
    local_attn_window=2048,
    lru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
        d_ff=128, vocab_size=256, local_attn_window=16, lru_width=64,
    )
