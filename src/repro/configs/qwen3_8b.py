"""qwen3-8b — dense decoder with qk-norm + GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
[hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    rope_theta=1000000.0,
    qk_norm=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
