"""xlstm-350m — alternating sLSTM / mLSTM recurrent blocks (no attention).

24L d_model=1024 4H d_ff=0 vocab=50304.  d_ff=0 ⇒ block-internal
projections only (xLSTM blocks carry their own up/down projections).
Pure recurrent: O(1) decode state, so long_500k decode runs; prefix reuse
is whole-prefix only (supports_partial_prefix=False).  [arXiv:2405.04517]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    supports_partial_prefix=False,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
        vocab_size=256,
    )
