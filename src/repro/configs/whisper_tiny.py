"""whisper-tiny — encoder-decoder ASR backbone; conv frontend is a STUB.

4L (enc) + 4L (dec) d_model=384 6H d_ff=1536 vocab=51865. ``input_specs``
feeds precomputed frame embeddings (B, frames, d_model) per assignment.
Encoder uses bidirectional attention over frames; decoder has causal
self-attn + cross-attn.  [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,            # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    enc_layers=4,
    enc_max_len=1500,        # 30s of audio at 50 frames/s (standard whisper)
    rope_theta=0.0,          # whisper uses learned/sinusoidal, not rope
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, enc_layers=2, enc_max_len=64,
    )
