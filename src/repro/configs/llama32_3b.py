"""llama3.2-3b — small llama3 dense decoder.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
24 heads do not divide the 16-way model axis: the sharding policy
replicates heads and shards d_ff instead (DESIGN.md §5).
[hf:meta-llama/Llama-3.2-3B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=48, num_heads=6, num_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=256,
    )
