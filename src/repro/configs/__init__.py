"""Architecture registry: ``--arch <id>`` resolves here.

``get_config(arch)`` returns the FULL assigned config; ``get_smoke(arch)``
returns the reduced same-family config used by CPU smoke tests.
``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for the
dry-run (no device allocation).
"""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig, MoEConfig, ShapeSpec, SHAPES, shape_applicable,
)

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x22b": "mixtral_8x22b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-67b": "deepseek_67b",
    "llama3.2-3b": "llama32_3b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen3-8b": "qwen3_8b",
    "internvl2-2b": "internvl2_2b",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch, shape) cell as ShapeDtypeStructs.

    train  -> {tokens, labels}
    prefill-> {tokens}
    decode -> {token} (one new token; the KV cache itself is created by the
              step factory, also as specs)
    Modality frontends are stubs: audio adds ``frames`` (B, enc_len, D)
    precomputed frame embeddings; vlm adds ``patch_embeds`` (B, P, D).
    """
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "decode":
        specs["token"] = jax.ShapeDtypeStruct((B,), i32)
    else:
        raise ValueError(shape.kind)

    if cfg.family == "audio" and shape.kind != "decode":
        # enc-dec: frame embeddings from the (stubbed) conv frontend
        enc_len = min(cfg.enc_max_len, S)
        specs["frames"] = jax.ShapeDtypeStruct((B, enc_len, cfg.d_model), bf16)
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), bf16)
    return specs


__all__ = [
    "ModelConfig", "MoEConfig", "ShapeSpec", "SHAPES", "ARCH_IDS",
    "get_config", "get_smoke", "all_configs", "input_specs",
    "shape_applicable",
]
