"""mixtral-8x22b — 8 experts top-2 + sliding-window attention.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
SWA window 4096 bounds the KV cache, so long_500k decode is runnable.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1000000.0,
    swa_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, swa_window=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
