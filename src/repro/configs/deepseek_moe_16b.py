"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16, i.e. MHA) d_ff=1408 (expert width) vocab=102400.
First layer uses a dense FFN (DeepSeekMoE keeps layer 0 dense).
[arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
)


def smoke_config() -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return CONFIG.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, vocab_size=256,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96,
                      num_shared_experts=1, first_dense_layers=1,
                      d_ff_dense=128),
    )
