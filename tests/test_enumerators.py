"""Data-scale binding enumerators (DESIGN.md §12.1): thousands-of-query
batches derived from rows/result-sets/CSVs, feeding the unchanged
consolidation + simulator path."""
import pytest

from benchmarks.common import run_halo
from repro.core.consolidate import consolidate
from repro.workloads import (build_enumerated_workload, build_workload,
                             enumerate_csv, enumerate_sql, enumerate_table)
from repro.workloads.minidb import MiniDB


@pytest.fixture()
def db():
    d = MiniDB()
    d.create_table("t", ["id", "cat", "val"], [
        (0, "a", 10), (1, "b", 20), (2, "a", 30), (3, "c", 40), (4, "a", 50)])
    return d


# ---------------------------------------------------------------------------
def test_enumerate_table_rows(db):
    b = enumerate_table(db, "t")
    assert len(b) == 5
    assert b[0] == {"id": "0", "cat": "a", "val": "10"}   # stringified
    assert enumerate_table(db, "t", limit=2) == b[:2]


def test_enumerate_table_params_and_where(db):
    b = enumerate_table(db, "t", params={"bucket": "cat"},
                        where="val >= 30")
    assert b == [{"bucket": "a"}, {"bucket": "c"}, {"bucket": "a"}]
    with pytest.raises(KeyError, match="available columns"):
        enumerate_table(db, "t", params={"x": "no_such_col"})


def test_enumerate_sql_projection_and_aggregates(db):
    b = enumerate_sql(db, "SELECT cat, count(*), sum(val) FROM t "
                          "GROUP BY cat",
                      params={"bucket": "cat", "n": "count(*)",
                              "total": "sum(val)"})
    assert {"bucket": "a", "n": "3", "total": "90"} in b
    assert len(b) == 3                      # one binding per group


def test_enumerate_csv(tmp_path):
    p = tmp_path / "rows.csv"
    p.write_text("name, score\nalice,10\nbob,20\n")
    b = enumerate_csv(str(p), params={"who": "name"})
    assert b == [{"who": "alice"}, {"who": "bob"}]
    assert enumerate_csv(str(p), limit=1) == [
        {"name": "alice", "score": "10"}]
    empty = tmp_path / "empty.csv"
    empty.write_text("")
    with pytest.raises(ValueError, match="no header row"):
        enumerate_csv(str(empty))


# ---------------------------------------------------------------------------
def test_ws_registered_in_sampled_library():
    """The data-scale template also works through the plain sampled
    ``build_workload`` registry."""
    g, bindings, dbname = build_workload("ws", 4, seed=0)
    assert dbname == "finewiki" and len(bindings) == 4
    assert {"fetch", "stats", "assess", "brief"} <= set(g.nodes)


def test_enumerated_unregistered_name_raises():
    with pytest.raises(KeyError, match="no enumeration registered"):
        build_enumerated_workload("w1", limit=4)


@pytest.mark.slow
def test_ws_enumerated_scale_through_simulator():
    """>= 2000 enumerated queries consolidate (per-topic stats coalesce
    to the topic count) and run through the simulator path whole."""
    g, bindings, dbname, db = build_enumerated_workload("ws", limit=2048)
    assert len(bindings) == 2048
    assert len({b["title"] for b in bindings}) == 2048      # one per row
    cons = consolidate(g, bindings)
    uniq = {nid: cons.macros[nid].n_unique for nid in g.nodes}
    topics = len({b["topic"] for b in bindings})
    assert uniq["stats"] == topics <= 8         # aggregate dedups per topic
    assert uniq["fetch"] == 2048                # per-row nodes do not
    rep = run_halo(g, cons, workers=3)
    assert rep.num_queries == 2048
    assert rep.makespan > 0
    # the enumerated batch's own database answers its SQL
    rows = db.execute("SELECT count(*) FROM pages")
    assert rows[0][0] >= 2048
