"""MiniDB SQL subset + EXPLAIN + prepared statements."""
import pytest

from repro.workloads.minidb import MiniDB, parse_sql


@pytest.fixture()
def db():
    d = MiniDB()
    d.create_table("t", ["id", "cat", "val"], [
        (0, "a", 10), (1, "b", 20), (2, "a", 30), (3, "c", 40), (4, "a", 50)])
    d.create_table("u", ["tid", "name"], [
        (0, "x"), (0, "y"), (2, "z"), (4, "w")])
    d.create_index("t", "cat")
    d.create_index("t", "id")
    return d


def test_filter_order_limit(db):
    rows = db.execute("SELECT id, val FROM t WHERE cat = 'a' "
                      "ORDER BY val DESC LIMIT 2")
    assert rows == [(4, 50), (2, 30)]


def test_range_filter(db):
    assert db.execute("SELECT id FROM t WHERE val >= 30") == \
        [(2,), (3,), (4,)]


def test_join(db):
    rows = db.execute("SELECT u.name FROM t JOIN u ON t.id = u.tid "
                      "WHERE t.cat = 'a'")
    assert sorted(rows) == [("w",), ("x",), ("y",), ("z",)]


def test_group_aggregate(db):
    rows = db.execute("SELECT cat, count(*), sum(val) FROM t GROUP BY cat")
    assert ("a", 3, 90) in rows and ("b", 1, 20) in rows


def test_global_aggregate(db):
    assert db.execute("SELECT avg(val) FROM t") == [(30.0,)]
    assert db.execute("SELECT count(*) FROM t WHERE cat != 'a'") == [(2,)]


def test_explain_index_cheaper_than_scan():
    """On a non-trivial table, an index probe beats a sequential scan
    (on the 5-row fixture the probe overhead rightly dominates)."""
    big = MiniDB()
    rows = [(i, f"c{i % 50}", i * 2) for i in range(20000)]
    big.create_table("t", ["id", "cat", "val"], rows)
    big.create_index("t", "cat")
    ix = big.explain("SELECT val FROM t WHERE cat = 'c7'")
    seq = big.explain("SELECT val FROM t WHERE val > 1")
    assert 0 < ix < seq


def test_prepared_statement_reuse(db):
    sql = "SELECT id FROM t WHERE cat = 'b'"
    db.execute(sql)
    before = db.prepared_hits
    db.execute(sql)
    assert db.prepared_hits == before + 1


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse_sql("DROP TABLE students")


# ---------------------------------------------------------------------------
# sqlite3 oracle cross-checks — the fixed-case arm of the property test
# (tests/test_minidb_property.py runs the randomized arm when hypothesis
# is installed; these pin the same comparison against stdlib sqlite3)
# ---------------------------------------------------------------------------

_ORACLE_ROWS = [(0, "a", 10), (1, "b", 20), (2, "a", 30), (3, "c", 40),
                (4, "a", 50), (5, "b", -7), (6, "c", 0)]

_ORACLE_QUERIES = [
    "SELECT id, val FROM t WHERE cat = 'a' ORDER BY id",
    "SELECT id FROM t WHERE val >= 20 ORDER BY id LIMIT 3",
    "SELECT id, cat, val FROM t WHERE val != 0",
    "SELECT cat, count(*), sum(val) FROM t GROUP BY cat",
    "SELECT cat, min(val), max(val), avg(val) FROM t WHERE val > -7 "
    "GROUP BY cat",
    "SELECT count(*), sum(val) FROM t WHERE cat != 'b'",
    "SELECT avg(val) FROM t",
]


def _oracle_norm(rows, ordered):
    out = [tuple(round(v, 6) if isinstance(v, float) else v for v in r)
           for r in rows]
    return out if ordered else sorted(out, key=repr)


@pytest.mark.parametrize("sql", _ORACLE_QUERIES)
def test_sqlite_oracle_agrees(sql):
    import sqlite3
    mdb = MiniDB()
    mdb.create_table("t", ["id", "cat", "val"], _ORACLE_ROWS)
    con = sqlite3.connect(":memory:")
    con.execute("CREATE TABLE t (id INTEGER, cat TEXT, val INTEGER)")
    con.executemany("INSERT INTO t VALUES (?, ?, ?)", _ORACLE_ROWS)
    ordered = "ORDER BY" in sql
    assert _oracle_norm(mdb.execute(sql), ordered) == \
        _oracle_norm(con.execute(sql).fetchall(), ordered)
