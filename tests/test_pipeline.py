"""Per-request CPU-GPU pipelining, online calibration and replanning.

Covers the Processor's fine-grained dataflow path (PAPER.md §5): results
published per request (not per macro-batch), event-driven tool
promotion, roofline-knob calibration from measured latencies, and
mid-run replan splicing — plus regression pins for the shared-default,
whole-prefix-credit and persistent-host stat-counting bugfixes.
"""
import pytest

from repro.core import (CostModel, EpochDPSolver, HARDWARE, HardwareCalibration,
                        LLMProfile, PAPER_MODELS, SolverConfig, consolidate)
from repro.core.graphspec import GraphSpec, NodeSpec, NodeType
from repro.core.state import WorkerContext
from repro.runtime import OnlineOptimizer, RealProcessor
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime


def _setup(wname, n, workers=2):
    g, bindings, dbname = build_workload(wname, n, seed=0)
    cons = consolidate(g, bindings)
    b = {}
    for nid in g.nodes:
        m = cons.macro(nid)
        b[nid] = m.n_logical if g.nodes[nid].is_llm() else m.n_unique
    cm = CostModel(g, HARDWARE["h200"], PAPER_MODELS, batch_sizes=b)
    plan = EpochDPSolver(g.llm_dag(), cm,
                         SolverConfig(num_workers=workers)).solve()
    return g, cons, dbname, cm, plan


def _models(g):
    from repro.configs import get_smoke
    names = {g.nodes[x].model for x in g.llm_nodes()}
    return {m: get_smoke("qwen3-1.7b").replace(name=m) for m in names}


def _proc(g, dbname, latency_scale=0.0, **kw):
    return RealProcessor(
        g, _models(g), ToolRuntime(build_database(dbname),
                                   latency_scale=latency_scale),
        num_workers=2, decode_cap=6, **kw)


# ---------------------------------------------------------------------------
# tentpole: per-request pipelining
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tool_starts_before_macro_batch_finishes():
    """A query's tool task must begin while the same macro-batch is still
    decoding its slower queries (the macro barrier would forbid this).

    max_batch=2 with 4 queries forces two admission waves for ``gen``:
    wave 1 retires strictly before wave 2, so wave-1 queries' tools run
    during wave-2 decode."""
    g, cons, dbname, _, plan = _setup("wt", 4)
    proc = _proc(g, dbname, latency_scale=1.0,
                 engine_kwargs={"max_batch": 2})
    rep = proc.run(cons, plan)
    gen_end = max(r.end for r in rep.records       # last submission wave
                  if r.kind == "llm" and r.node == "gen")
    first_tool = min(r.start for r in rep.records if r.kind == "tool")
    assert first_tool < gen_end, (
        f"no overlap: first tool at {first_tool:.3f}, "
        f"gen macro-batch finished at {gen_end:.3f}")
    assert rep.extra["cpu_gpu_overlap_s"] > 0


@pytest.mark.slow
def test_pipelined_outputs_bitwise_match_barrier_and_replan():
    """Temperature-0 outputs are invariant to pipelining AND to forced
    mid-run replanning (semantics preservation, the §5 contract)."""
    g, cons, dbname, _, plan = _setup("wt", 4)
    base = _proc(g, dbname, pipelining=False).run(cons, plan)
    piped = _proc(g, dbname, pipelining=True).run(cons, plan)
    assert piped.results() == base.results()

    _, _, _, cm, _ = _setup("wt", 4)
    opt = OnlineOptimizer(cm, drift_threshold=0.0)
    replanned = _proc(g, dbname, pipelining=True).run(
        cons, plan, optimizer=opt)
    assert replanned.results() == base.results()
    assert replanned.extra["replans"] == replanned.extra["plan_splices"]


# ---------------------------------------------------------------------------
# calibration + replanning
# ---------------------------------------------------------------------------

def test_calibration_convergence():
    """The EWMA-fit roofline knobs tighten predicted-vs-observed error
    geometrically under a stable observed latency."""
    g, _, _, cm, _ = _setup("wt", 4)
    spec = g.nodes["gen"]
    tp0, td0 = cm.infer_breakdown(spec, 4)
    true_seconds = 3.0 * (tp0 + td0)          # machine 3x slower than model
    calib = HardwareCalibration(cm.hw)
    errors = []
    for _ in range(8):
        tp, td = cm.infer_breakdown(spec, 4)
        errors.append(abs((tp + td) - true_seconds) / true_seconds)
        calib.observe(tp, td, true_seconds)
        cm.hw = calib.profile()
    assert errors[-1] < 0.05
    assert errors[-1] < errors[0]
    assert all(b <= a + 1e-12 for a, b in zip(errors, errors[1:]))
    d = calib.deltas()
    assert d["samples"] == 8 and d["mfu_eff"] != d["mfu_base"]


def test_replan_splice_is_valid_plan():
    """After drift triggers a replan, claimed-prefix + re-solved tail is
    a valid ExecutionPlan and the board covers every remaining node."""
    from repro.runtime.coordinator import PlanBoard
    g, cons, dbname, cm, plan = _setup("w1", 4)
    dag = g.llm_dag()
    assert len(plan.epochs) >= 2, "need a multi-epoch plan for this test"
    board = PlanBoard(plan, dag, 2)
    opt = OnlineOptimizer(cm, drift_threshold=0.0)
    opt.solver_config.num_workers = 2
    opt.attach_plan(plan)

    e0 = plan.epochs[0]
    for comp, w in zip(e0.components, e0.workers):
        for nid in comp:
            assert board.try_claim(w) == nid
            opt.observe_llm(nid, cons.n_queries, 123.0, f"gpu{w}")
    assert opt.maybe_replan(board) is True
    assert opt.replans == 1 and board.splices == 1
    spliced = opt.spliced_plan
    spliced.validate(dag)                     # raises on a bad splice
    planned = set(board.claimed) | {
        n for seq in board.seqs for n in seq}
    assert planned == set(dag.node_ids)
    assert opt.epoch_drifts and opt.epoch_drifts[0]["drift"] > 0


def test_splice_routes_dead_worker_tail_to_overflow():
    """Tail work planned onto an abandoned worker must stay claimable by
    the survivors (via overflow), not strand on the dead sequence."""
    from repro.runtime.coordinator import PlanBoard
    g, cons, dbname, cm, plan = _setup("w1", 2)
    dag = g.llm_dag()
    board = PlanBoard(plan, dag, 2)
    board.abandon(0)
    board.splice(plan)          # re-solve "tail" = whole plan (0 claimed)
    assert board.seqs[0] == []
    assert set(board.overflow) | set(board.seqs[1]) == set(dag.node_ids)
    # and a survivor can actually claim an orphaned, releasable node
    assert board.try_claim(1) is not None


@pytest.mark.slow
def test_worker_failure_recovery_completes():
    """die_after: a failed worker's remaining nodes are picked up by the
    survivor the moment they are claimable."""
    g, cons, dbname, _, plan = _setup("w+", 2)
    rep = _proc(g, dbname).run(cons, plan, die_after={0: 1})
    assert len(rep.results()) == 2 * len(g.nodes)


def test_wave_span_union_does_not_double_count():
    """Overlapping submission waves of one continuous batch contribute
    their union, not their sum, to observed node time."""
    u = OnlineOptimizer._union_seconds
    assert u([(10.0, 15.0), (11.0, 15.5), (20.0, 21.0)]) == 6.5
    assert u([]) == 0.0
    g, _, _, cm, _ = _setup("wt", 2)
    opt = OnlineOptimizer(cm)
    opt.observe_llm("gen", 1, 5.0, "gpu0", node_complete=False,
                    span=(10.0, 15.0))
    opt.observe_llm("gen", 1, 4.5, "gpu0", node_complete=True,
                    span=(11.0, 15.5))
    assert opt._llm_obs["gen"] == ("gpu0", 5.5)


def test_operator_profiler_feedback_via_optimizer():
    g, _, _, cm, _ = _setup("wt", 2)
    opt = OnlineOptimizer(cm)
    opt.observe_tool("verify", "http", 0.25)
    opt.observe_tool("verify", "http", 0.35)
    est = cm.profiler.estimate(g.nodes["verify"])
    assert 0.25 <= est <= 0.35
    assert cm.profiler.observations == 2


# ---------------------------------------------------------------------------
# bugfix pins
# ---------------------------------------------------------------------------

def test_cost_model_weights_not_shared_between_instances():
    g, _, _, cm1, _ = _setup("wt", 2)
    cm1.weights.mu = 0.123
    _, _, _, cm2, _ = _setup("wt", 2)
    assert cm2.weights.mu != 0.123
    assert cm1.weights is not cm2.weights


def test_solver_config_not_shared_between_instances():
    g, cons, _, cm, _ = _setup("wt", 2)
    s1 = EpochDPSolver(g.llm_dag(), cm)
    s1.cfg.beam = 1
    s2 = EpochDPSolver(g.llm_dag(), cm)
    assert s2.cfg.beam != 1
    assert s1.cfg is not s2.cfg


def test_whole_prefix_credit_reachable_for_recurrent_archs():
    nodes = [NodeSpec("a", NodeType.LLM, model="rec", est_prompt_tokens=100),
             NodeSpec("b", NodeType.LLM, model="rec", est_prompt_tokens=100)]
    g = GraphSpec("t", nodes, [("a", "b")])
    rec = LLMProfile.from_params("rec", 1e9, 8, 4, 64,
                                 supports_partial_prefix=False)
    ctx = WorkerContext(model="rec", warm=("a",))
    # snapshot covers the whole prompt -> full credit
    cm = CostModel(g, HARDWARE["h200"], {"rec": rec},
                   avg_context_tokens=128.0)
    assert cm.effective_prefill_tokens(g.nodes["b"], ctx, ["a"]) == 0.0
    # snapshot shorter than the prompt -> no partial credit possible
    cm2 = CostModel(g, HARDWARE["h200"], {"rec": rec},
                    avg_context_tokens=64.0)
    assert cm2.effective_prefill_tokens(g.nodes["b"], ctx, ["a"]) == 100.0


@pytest.mark.slow
def test_persistent_host_stats_report_per_run_deltas():
    """Two micro-batches on the same hosts: each report carries only its
    own counts (seed bug: run 2 re-reported run 1's counters too)."""
    from repro.runtime.executors import EngineHost
    g, cons, dbname, _, plan = _setup("w+", 3)
    proc = _proc(g, dbname)
    hosts = [EngineHost(proc.model_configs, seed=proc.seed)
             for _ in range(2)]
    try:
        r1 = proc.run(cons, plan, hosts=hosts)
        r2 = proc.run(cons, plan, hosts=hosts)
        engines = [e for h in hosts for e in h._engines.values()]
        for key in ("admission_waves", "tokens_reused", "pages_shared"):
            total = sum(getattr(e.stats, key) for e in engines)
            assert r1.extra[key] + r2.extra[key] == total, key
        assert r1.extra["admission_waves"] > 0
    finally:
        for h in hosts:
            h.shutdown()


@pytest.mark.slow
def test_tool_records_attributed_to_real_nodes():
    g, cons, dbname, _, plan = _setup("wt", 3)
    rep = _proc(g, dbname).run(cons, plan)
    tool_nodes = set(g.tool_nodes())
    recs = [r for r in rep.records if r.kind == "tool"]
    assert recs
    assert all(r.node in tool_nodes for r in recs)
