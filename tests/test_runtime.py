"""Runtime: simulator behaviour + real-processor semantics (fast paths)."""
import pytest

from repro.core import (CostModel, EpochDPSolver, HARDWARE, PAPER_MODELS,
                        SolverConfig, consolidate, opwise_plan)
from repro.runtime import (OpWiseSimulator, OnlineSimulator, RealProcessor,
                           SimulatedProcessor)
from repro.runtime.checkpoint import load_batch_state, save_batch_state
from repro.runtime.coordinator import BatchState
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime


def _setup(wname="w1", n=64):
    g, bindings, dbname = build_workload(wname, n, seed=0)
    cons = consolidate(g, bindings)
    return g, cons, bindings, dbname


def _cm(g, cons, logical=False, **kw):
    b = {}
    for nid in g.nodes:
        m = cons.macro(nid)
        b[nid] = m.n_logical if (g.nodes[nid].is_llm() or logical) \
            else m.n_unique
    return CostModel(g, HARDWARE["h200"], PAPER_MODELS, batch_sizes=b, **kw)


def _plan(g, cons, workers=3):
    return EpochDPSolver(g.llm_dag(), _cm(g, cons),
                         SolverConfig(num_workers=workers)).solve()


def test_simulator_completes_all_nodes():
    g, cons, _, _ = _setup()
    plan = _plan(g, cons)
    rep = SimulatedProcessor(g, _cm(g, cons), 3).run(cons, plan)
    llm_nodes = {r.node for r in rep.records if r.kind == "llm"}
    tool_nodes = {r.node for r in rep.records if r.kind == "tool"}
    assert llm_nodes == set(g.llm_nodes())
    assert tool_nodes == set(g.tool_nodes())
    assert rep.makespan > 0


def test_coalescing_reduces_tool_work():
    g, cons, _, _ = _setup()
    plan = _plan(g, cons)
    with_c = SimulatedProcessor(g, _cm(g, cons), 3).run(cons, plan)
    without = SimulatedProcessor(g, _cm(g, cons, logical=True), 3,
                                 coalescing=False).run(cons, plan)
    assert with_c.coalesce_stats["tool_physical"] < \
        without.coalesce_stats["tool_physical"]
    assert with_c.makespan < without.makespan


def test_opwise_slower_than_halo():
    g, cons, _, _ = _setup("w1", 256)
    plan = _plan(g, cons)
    halo = SimulatedProcessor(g, _cm(g, cons), 3).run(cons, plan)
    ow = OpWiseSimulator(g, _cm(g, cons), 3).run(cons)
    assert ow.makespan > halo.makespan


def test_simulated_worker_failure_completes():
    g, cons, _, _ = _setup()
    plan = _plan(g, cons)
    sp = SimulatedProcessor(g, _cm(g, cons), 3)
    sp.sim.add_failure(1.0, 1)
    rep = sp.run(cons, plan)
    assert {r.node for r in rep.records if r.kind == "llm"} == \
        set(g.llm_nodes())
    assert "failed_worker_1" in rep.extra


def test_online_throughput_positive():
    g, cons, bindings, _ = _setup("w+", 32)
    plan = _plan(g, cons)
    batches = []
    for lo in range(0, 32, 8):
        cb = consolidate(g, bindings[lo:lo + 8])
        batches.append((cb, plan))
    rep = OnlineSimulator(g, _cm(g, cons), 3).run(batches, 2.0)
    assert rep.throughput_qps() > 0
    assert len(rep.query_completion) == 32


def test_batch_state_checkpoint_roundtrip(tmp_path):
    g, cons, _, _ = _setup("w+", 4)
    st = BatchState(g, 4)
    for q in range(4):
        st.set_result(q, "draft", f"r{q}")
    p = str(tmp_path / "ck.json")
    save_batch_state(st, p)
    st2 = BatchState(g, 4)
    n = load_batch_state(st2, p)
    assert n == 4 and st2.results == st.results
    assert "draft" in st2.macro_done


@pytest.mark.slow
def test_real_processor_semantics_wplus():
    """Real engines + coalescing on the pure-LLM chain: outputs invariant
    to plan choice and coalescing (semantics preserving)."""
    from repro.configs import get_smoke
    g, cons, _, dbname = _setup("w+", 3)
    models = {m: get_smoke("qwen3-1.7b").replace(name=m)
              for m in ("qwen3-14b", "qwen3-32b", "gpt-oss-20b")}
    plan = _plan(g, cons, workers=2)
    r1 = RealProcessor(g, models, ToolRuntime(build_database(dbname),
                                              latency_scale=0.0),
                       num_workers=2, decode_cap=3).run(cons, plan)
    ow = opwise_plan(g.llm_dag(), _cm(g, cons), 2)
    r2 = RealProcessor(g, models, ToolRuntime(build_database(dbname),
                                              latency_scale=0.0),
                       num_workers=2, decode_cap=3).run(cons, ow)
    assert r1.results() == r2.results()
