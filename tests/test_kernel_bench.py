"""Autotune table plumbing + kernel roofline accounting.

Covers the pieces that make the speed-of-light decode kernel safe to
ship: ``kernel_config`` resolution (checked-in table -> exact shape key
-> env overrides), the ``persist_table`` refusal to write tables
measured under the Pallas interpreter, and the bytes/FLOPs cost model
the %-of-roofline rows score against.
"""
import json

import pytest

from benchmarks import kernel_bench
from repro.configs.base import HBM_BW, PEAK_FLOPS_BF16
from repro.kernels.paged_decode_attention import ops as paged_ops
from repro.launch.roofline import (kernel_time_bound, paged_decode_cost,
                                   pct_of_roofline)


# ---------------------------------------------------------------------------
# kernel_config resolution
# ---------------------------------------------------------------------------

def test_shape_key_format():
    assert paged_ops.shape_key(64, 8, 128, 4) == "ps64-hkv8-dh128-g4"


def test_kernel_config_default_and_exact_key():
    """The checked-in table's default applies to unknown shapes; an
    exact shape key overrides it."""
    kc = paged_ops.kernel_config(999, 999, 999, 999)   # no such key
    assert kc["variant"] in paged_ops.VARIANTS
    assert kc["pages_per_block"] >= 1
    assert kc["grid_layout"] in ("bh", "hb")
    # ps64-hkv4-dh64-g8 is a seeded entry with ppb=8
    kc = paged_ops.kernel_config(64, 4, 64, 8)
    assert kc["pages_per_block"] == 8


def test_kernel_config_env_table_override(tmp_path, monkeypatch):
    """REPRO_KERNEL_AUTOTUNE points at an alternate table file."""
    table = {"configs": {
        "default": {"variant": "blocked", "pages_per_block": 2,
                    "grid_layout": "hb"},
        "ps32-hkv4-dh64-g2": {"variant": "single", "pages_per_block": 1,
                              "grid_layout": "bh"}}}
    p = tmp_path / "table.json"
    p.write_text(json.dumps(table))
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE", str(p))
    paged_ops._load_table.cache_clear()
    try:
        assert paged_ops.kernel_config(7, 7, 7, 7) == {
            "variant": "blocked", "pages_per_block": 2,
            "grid_layout": "hb"}
        assert paged_ops.kernel_config(32, 4, 64, 2)["variant"] == "single"
    finally:
        paged_ops._load_table.cache_clear()


def test_kernel_config_env_variant_force(monkeypatch):
    """REPRO_PAGED_VARIANT force-overrides whatever the table says."""
    monkeypatch.setenv("REPRO_PAGED_VARIANT", "single")
    assert paged_ops.kernel_config(64, 8, 128, 8)["variant"] == "single"
    monkeypatch.setenv("REPRO_PAGED_VARIANT", "fused")
    assert paged_ops.kernel_config(64, 8, 128, 8)["variant"] == "fused"


def test_kernel_config_unreadable_table_falls_back(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_AUTOTUNE",
                       str(tmp_path / "missing.json"))
    paged_ops._load_table.cache_clear()
    try:
        kc = paged_ops.kernel_config(64, 8, 128, 8)
        assert kc["variant"] in paged_ops.VARIANTS   # built-in defaults
    finally:
        paged_ops._load_table.cache_clear()


def test_checked_in_table_is_well_formed():
    with open(paged_ops._DEFAULT_TABLE) as f:
        table = json.load(f)
    assert "default" in table["configs"]
    for key, kc in table["configs"].items():
        assert kc["variant"] in paged_ops.VARIANTS, key
        assert kc["pages_per_block"] >= 1
        assert kc["grid_layout"] in ("bh", "hb")


# ---------------------------------------------------------------------------
# persist refusal (interpret-mode measurements must never seed the table)
# ---------------------------------------------------------------------------

def test_persist_refuses_interpret_rows(tmp_path):
    rows = [{"shape_key": "ps8-hkv2-dh16-g2", "variant": "blocked",
             "pages_per_block": 2, "grid_layout": "bh",
             "tokens_per_s": 100.0, "interpret": True}]
    with pytest.raises(RuntimeError, match="interpret"):
        kernel_bench.persist_table(rows, str(tmp_path / "t.json"))
    assert not (tmp_path / "t.json").exists()


def test_persist_writes_winners_for_hardware_rows(tmp_path):
    rows = [
        {"shape_key": "k", "variant": "single", "pages_per_block": 1,
         "grid_layout": "bh", "tokens_per_s": 10.0, "interpret": False},
        {"shape_key": "k", "variant": "fused", "pages_per_block": 4,
         "grid_layout": "hb", "tokens_per_s": 30.0, "interpret": False},
    ]
    path = kernel_bench.persist_table(rows, str(tmp_path / "t.json"))
    with open(path) as f:
        table = json.load(f)
    assert table["configs"]["k"] == {"variant": "fused",
                                     "pages_per_block": 4,
                                     "grid_layout": "hb"}
    assert "default" in table["configs"]


# ---------------------------------------------------------------------------
# roofline cost model
# ---------------------------------------------------------------------------

def test_kernel_time_bound_picks_slower_term():
    assert kernel_time_bound(HBM_BW, 0.0) == pytest.approx(1.0)
    assert kernel_time_bound(0.0, PEAK_FLOPS_BF16) == pytest.approx(1.0)
    assert pct_of_roofline(2.0, HBM_BW, 0.0) == pytest.approx(50.0)


def test_paged_decode_cost_scales_with_live_pages():
    """Bytes follow the LIVE page count (early-out) and the fused
    append adds exactly the new token's KV."""
    base, _ = paged_decode_cost(2, 4, 2, 16, 8, 4)
    half, _ = paged_decode_cost(2, 4, 2, 16, 8, 4,
                                lengths=[8 * 4 - 1, -1])
    assert half < base
    fused, _ = paged_decode_cost(2, 4, 2, 16, 8, 4, fused=True)
    assert fused - base == 2 * 2 * 2 * 16 * 4      # 2B rows of K and V
    _, flops = paged_decode_cost(2, 4, 2, 16, 8, 4)
    assert flops == 4.0 * 4 * 16 * 2 * (8 * 4)     # 4·H·Dh·tokens


# ---------------------------------------------------------------------------
# sweep rows (interpret mode, tiny shape — structure only, no timing claims)
# ---------------------------------------------------------------------------

def test_bench_rows_smoke_structure():
    rows = kernel_bench.bench_rows(
        smoke=True, reps=1, shapes=[("tiny", 2, 4, 2, 16, 8, 2)])
    assert len(rows) == 5                           # trimmed candidate grid
    for r in rows:
        assert r["interpret"] is True               # CPU host
        assert r["tokens_per_s"] > 0
        # interpreter timings sit far off the roofline; the rounded
        # figure may be 0.00 but can never exceed the bound
        assert 0 <= r["pct_of_roofline"] <= 100
    assert kernel_bench.winners(rows)               # one winner per key
