"""Subprocess harness for the kill -9 resume test (DESIGN.md §12.2).

Runs one real-engine ``wt`` batch with a durable jobstore at argv[1]
and prints a JSON summary on success.  The parent test runs this three
ways: uninterrupted (baseline), SIGKILLed mid-batch, and resumed
against the killed run's journal — asserting the resumed outputs are
bitwise-identical with zero re-executed signatures.
"""
import json
import sys

from benchmarks.common import make_real_processor


def main() -> None:
    jobstore_path = sys.argv[1]
    proc, g, cons, bindings, plan = make_real_processor(
        "wt", n=6, workers=2, decode_cap=3, seed=0,
        latency_scale=3.0,                  # slow http: killable window
        jobstore_path=jobstore_path, jobstore_fsync_every=1)
    rep = proc.run(cons, plan)
    print(json.dumps({"results": rep.extra["results"],
                      "jobstore": rep.extra["jobstore"]}))


if __name__ == "__main__":
    main()
