"""Property-based MiniDB correctness: random tables + random queries
from the supported SQL subset, cross-checked against a sqlite3 oracle.

The strategy stays inside the subset's DOCUMENTED semantics (see
``tests/test_minidb.py`` for the fixed oracle cases that run even
without hypothesis):

* no NULLs (minidb's count(col) counts all rows);
* ORDER BY only on a projected, unique column (minidb skips unprojected
  sort keys; ties are engine-defined);
* LIMIT only with ORDER BY (otherwise row order is engine-defined, so
  unordered results compare as sorted multisets);
* projections are all-bare or all-aggregate (mixing takes the first
  group's scalar in minidb).
"""
import sqlite3
import string

import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -e .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.workloads.minidb import MiniDB  # noqa: E402

CATS = list(string.ascii_lowercase[:4])


def _norm(rows, ordered):
    out = [tuple(round(v, 6) if isinstance(v, float) else v for v in r)
           for r in rows]
    return out if ordered else sorted(out, key=repr)


def _oracle(rows):
    con = sqlite3.connect(":memory:")
    con.execute("CREATE TABLE t (id INTEGER, cat TEXT, val INTEGER)")
    con.executemany("INSERT INTO t VALUES (?, ?, ?)", rows)
    return con


rows_st = st.lists(
    st.tuples(st.integers(0, 10 ** 6), st.sampled_from(CATS),
              st.integers(-100, 100)),
    min_size=1, max_size=40,
    unique_by=lambda r: r[0])               # id unique: a stable sort key

where_st = st.one_of(
    st.none(),
    st.tuples(st.sampled_from(["val", "id"]),
              st.sampled_from(["=", "!=", "<", "<=", ">", ">="]),
              st.integers(-50, 50)),
    st.tuples(st.just("cat"), st.sampled_from(["=", "!="]),
              st.sampled_from(CATS)))


def _where_sql(w):
    if w is None:
        return ""
    col, op, v = w
    lit = f"'{v}'" if isinstance(v, str) else str(v)
    return f" WHERE {col} {op} {lit}"


@settings(max_examples=60, deadline=None)
@given(rows=rows_st, where=where_st,
       cols=st.sampled_from([("id",), ("cat", "val"), ("id", "cat", "val")]),
       order=st.booleans(), limit=st.one_of(st.none(), st.integers(1, 5)))
def test_projection_filter_order_limit_match_sqlite(rows, where, cols,
                                                    order, limit):
    sql = f"SELECT {', '.join(cols)} FROM t{_where_sql(where)}"
    ordered = order and "id" in cols        # unique + projected only
    if ordered:
        sql += " ORDER BY id"
        if limit is not None:
            sql += f" LIMIT {limit}"        # LIMIT needs a defined order
    db = MiniDB()
    db.create_table("t", ["id", "cat", "val"], rows)
    con = _oracle(rows)
    assert _norm(db.execute(sql), ordered) == \
        _norm(con.execute(sql).fetchall(), ordered)


@settings(max_examples=60, deadline=None)
@given(rows=rows_st, where=where_st,
       aggs=st.lists(st.sampled_from(
           ["count(*)", "sum(val)", "avg(val)", "min(val)", "max(val)"]),
           min_size=1, max_size=3, unique=True),
       group=st.booleans())
def test_aggregates_match_sqlite(rows, where, aggs, group):
    head = (["cat"] if group else []) + aggs
    sql = f"SELECT {', '.join(head)} FROM t{_where_sql(where)}"
    if group:
        sql += " GROUP BY cat"
    db = MiniDB()
    db.create_table("t", ["id", "cat", "val"], rows)
    con = _oracle(rows)
    mine, theirs = db.execute(sql), con.execute(sql).fetchall()
    if any(v is None for r in theirs for v in r):
        return          # empty global aggregate: NULL semantics differ
    assert _norm(mine, ordered=False) == _norm(theirs, ordered=False)


@settings(max_examples=30, deadline=None)
@given(rows=rows_st,
       probe=st.tuples(st.sampled_from(CATS),
                       st.sampled_from(["=", "!="])))
def test_index_never_changes_results(rows, probe):
    """An index is a pure access-path change: results identical."""
    cat, op = probe
    sql = f"SELECT id, val FROM t WHERE cat {op} '{cat}'"
    plain, indexed = MiniDB(), MiniDB()
    for db in (plain, indexed):
        db.create_table("t", ["id", "cat", "val"], rows)
    indexed.create_index("t", "cat")
    assert _norm(plain.execute(sql), False) == \
        _norm(indexed.execute(sql), False)
