"""Halo core: parser decoupling, consolidation, DP solver vs oracle."""

from repro.core import (BranchAndBoundOracle, CostModel, EpochDPSolver,
                        HARDWARE, PAPER_MODELS, SCHEDULERS, SolverConfig,
                        consolidate, optimality_score, parse_workflow)
from repro.core.parser import render

WF = {
    "name": "t",
    "nodes": [
        {"id": "a", "type": "llm", "model": "qwen3-14b",
         "prompt": "Use {{sql: SELECT x FROM t WHERE k='$p'}} for $p",
         "est_prompt_tokens": 64},
        {"id": "b", "type": "llm", "model": "qwen3-32b",
         "prompt": "Refine ${a} via {{http: GET /x?q=$p}}",
         "est_prompt_tokens": 96},
        {"id": "c", "type": "llm", "model": "qwen3-14b",
         "prompt": "Check ${a}", "est_prompt_tokens": 64},
        {"id": "d", "type": "llm", "model": "qwen3-32b",
         "prompt": "Merge ${b} and ${c}", "est_prompt_tokens": 128},
    ],
}


def test_parser_dependency_decoupling():
    g = parse_workflow(WF)
    assert "a__sql0" in g.nodes and g.nodes["a__sql0"].op == "sql"
    assert "b__http0" in g.nodes
    assert ("a__sql0", "a") in g.edges
    assert ("a", "b") in g.edges and ("a", "c") in g.edges
    assert "${a__sql0}" in g.nodes["a"].prompt      # directive replaced
    dag = g.llm_dag()
    assert set(dag.node_ids) == {"a", "b", "c", "d"}
    assert ("a", "b") in dag.edges and ("c", "d") in dag.edges


def test_render_binding_and_upstream():
    out = render("Use ${a} for $p and $pp", {"p": "X", "pp": "Y"},
                 {"a": "RESULT"})
    assert out == "Use RESULT for X and Y"


def test_consolidation_influence_dedup():
    g = parse_workflow(WF)
    cons = consolidate(g, [{"p": "x"}, {"p": "y"}, {"p": "x"}])
    # node a: influenced by p only -> 2 unique of 3
    assert cons.macro("a").n_unique == 2
    assert cons.macro("a__sql0").n_unique == 2
    assert cons.macro("d").n_unique == 2            # transitive influence
    assert cons.macro("a").n_logical == 3


def _cm(g, n=4):
    return CostModel(g, HARDWARE["h200"], PAPER_MODELS,
                     batch_sizes={nid: n for nid in g.nodes})


def test_dp_plan_valid_and_beats_baselines():
    g = parse_workflow(WF)
    dag = g.llm_dag()
    cm = _cm(g)
    plan = EpochDPSolver(dag, cm, SolverConfig(num_workers=2)).solve()
    plan.validate(dag)                              # raises on violation
    for name, fn in SCHEDULERS.items():
        base = fn(dag, _cm(g), 2, 0) if name == "random" else fn(dag, _cm(g), 2)
        assert plan.predicted_cost <= base.predicted_cost + 1e-6, name


def test_dp_matches_oracle_colocation():
    g = parse_workflow(WF)
    dag = g.llm_dag()
    cm = _cm(g)
    plan = EpochDPSolver(dag, cm, SolverConfig(num_workers=2)).solve()
    res = BranchAndBoundOracle(dag, cm, 2, time_limit=20).solve()
    opt_halo = optimality_score(plan, res.plan, 2)
    opt_rand = optimality_score(SCHEDULERS["random"](dag, _cm(g), 2, 3),
                                res.plan, 2)
    assert opt_halo >= opt_rand
    assert opt_halo >= 0.5
    # DP cost is close to the oracle makespan-optimal schedule
    assert plan.predicted_cost <= 1.5 * res.makespan + 1.0


def test_model_switch_cost_drives_chaining():
    """Same-model chains must be cheaper than alternating models."""
    from repro.core.state import WorkerContext
    g = parse_workflow(WF)
    cm = _cm(g)
    ctx = WorkerContext()
    t_a, ctx_a = cm.t_node("a", ctx, frozenset())
    # running c (same model qwen3-14b) after a: no switch cost
    t_c_after_a, _ = cm.t_node("c", ctx_a, frozenset({"a"}))
    t_c_fresh, _ = cm.t_node("c", WorkerContext(), frozenset({"a"}))
    assert t_c_after_a < t_c_fresh


def test_prefix_discount_reduces_cost():
    g = parse_workflow(WF)
    cm = _cm(g)
    from repro.core.state import WorkerContext
    warm = WorkerContext(model="qwen3-32b", warm=("b",))
    cold = WorkerContext(model="qwen3-32b", warm=())
    t_warm = cm.t_infer(g.nodes["d"], warm, ["b", "c"])
    t_cold = cm.t_infer(g.nodes["d"], cold, ["b", "c"])
    assert t_warm < t_cold
