"""kill -9 mid-batch, then resume (DESIGN.md §12.2).

The acceptance property: SIGKILL a real run mid-batch, re-run against
the surviving journal — the resumed run completes, re-executes ZERO
already-journaled signatures, and its outputs are bitwise-identical to
an uninterrupted run's.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_CHILD = os.path.join(_REPO, "tests", "_resume_child.py")


def _child_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_REPO, "src"), _REPO]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    env.pop("XLA_FLAGS", None)              # no inherited device carving
    return env


def _run_child(jobstore, timeout=240):
    out = subprocess.run(
        [sys.executable, _CHILD, jobstore], env=_child_env(),
        capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _data_lines(path):
    try:
        with open(path) as f:
            return sum(1 for line in f if '"k"' in line)
    except FileNotFoundError:
        return 0


@pytest.mark.slow
def test_kill9_resume_bitwise_and_zero_reexecution(tmp_path):
    # arm 1: uninterrupted baseline
    baseline = _run_child(str(tmp_path / "baseline.jsonl"))
    assert baseline["jobstore"]["re_executed_signatures"] == 0

    # arm 2: SIGKILL once >= 2 results hit the journal (mid-batch: the
    # run is seconds long, the first tool results land almost at once)
    journal = str(tmp_path / "killed.jsonl")
    child = subprocess.Popen([sys.executable, _CHILD, journal],
                             env=_child_env(), stdout=subprocess.PIPE,
                             stderr=subprocess.DEVNULL)
    try:
        deadline = time.monotonic() + 180
        while _data_lines(journal) < 2:
            if child.poll() is not None:
                pytest.fail("child finished before it could be killed; "
                            "no mid-batch window to test")
            if time.monotonic() > deadline:
                pytest.fail("journal never reached 2 results")
            time.sleep(0.02)
        child.send_signal(signal.SIGKILL)
        child.wait(timeout=60)
    finally:
        if child.poll() is None:
            child.kill()
    assert child.returncode == -signal.SIGKILL
    journaled = _data_lines(journal)
    assert journaled >= 2

    # arm 3: resume against the killed run's journal
    resumed = _run_child(journal)
    js = resumed["jobstore"]
    assert js["re_executed_signatures"] == 0        # nothing re-paid
    assert js["restored_signatures"] >= journaled - 1   # minus torn tail
    assert js["restored_results"] > 0
    assert resumed["results"] == baseline["results"]    # bitwise equal
