"""Fixture: a len()-derived batch size flows into a jitted entry's
input shape without _PF_QUANTUM-class bucketing — every distinct
batch recompiles."""
import jax
import jax.numpy as jnp


def _fn(x):
    return x * 2


_step = jax.jit(_fn, static_argnums=())


def run(tokens):
    n = len(tokens)
    x = jnp.zeros((n, 4))           # <- unbucketed shape, must be flagged
    return _step(x)
