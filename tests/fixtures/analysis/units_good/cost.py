"""units fixture: the same cost terms, dimensionally sound."""
from dataclasses import dataclass


@dataclass
class Hw:
    hbm_bw: float = 1e12        # unit: bytes/s @hbm
    link_bw: float = 1e10       # unit: bytes/s @link
    host_bw: float = 1e9        # unit: bytes/s @host
    dispatch: float = 1e-4      # unit: s


@dataclass
class Llm:
    param_bytes: float = 1e9    # unit: bytes @weights
    kv_per_tok: float = 1e5     # unit: bytes/token @kv


class Cost:
    def __init__(self, hw: Hw, llm: Llm):
        self.hw = hw
        self.llm = llm

    # unit: tokens=tokens -> s
    def t_migrate(self, tokens):
        kv = self.llm.kv_per_tok * tokens
        return kv / self.hw.link_bw + self.hw.dispatch

    # unit: -> s
    def t_step(self):
        return self.llm.param_bytes / self.hw.hbm_bw + self.hw.dispatch
