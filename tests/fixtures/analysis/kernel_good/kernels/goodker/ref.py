"""Reference implementation for the goodker fixture package."""


def apply_ref(x):
    return x
