"""Public entry for the goodker fixture package."""
from .kernel import good_kernel


def apply(x, block_s=256, interpret=False):
    return good_kernel(x, block_s=block_s, interpret=interpret)
