"""kernelcheck fixture: a contract-clean wrapper (never imported)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(x_ref, o_ref, acc_ref):
    o_ref[...] = x_ref[...]


# vmem-budget: 2.0 MiB @ block_s=256 S=4096 D=512
def good_kernel(x, *, block_s: int, interpret: bool = False):
    """x: (B, S, D); S % block_s == 0."""
    B, S, D = x.shape
    bs = min(block_s, S)
    assert S % bs == 0
    grid = (B, S // bs)

    return pl.pallas_call(
        _body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, D), lambda b, it: (b, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, D), lambda b, it: (b, it, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs, D), jnp.float32)],
        interpret=interpret,
    )(x)
