"""devmem fixture: the same shapes, disciplined."""
import jax
import jax.numpy as jnp
import numpy as np


class Pool:
    def __init__(self, n):
        self.k = jnp.zeros((n, 4))       # memspace: device
        self.v = jnp.zeros((n, 4))       # memspace: device
        self.meta = np.zeros((n,))       # memspace: host

    def adopt(self, k, v):
        self.k = k
        self.v = v

    # memspace: staging (the one sanctioned D2H boundary)
    def export(self):
        return np.asarray(self.k), np.asarray(self.v)


class Engine:
    def __init__(self, pool: Pool):
        self.pool = pool
        donate = (1, 2)
        self._step = jax.jit(lambda p, k, v: (p, k, v),
                             donate_argnums=donate)
        self.params = jnp.zeros((4,))    # memspace: device

    def hot_step(self, pool: Pool):
        logits, new_k, new_v = self._step(self.params, pool.k, pool.v)
        pool.adopt(new_k, new_v)         # rebinds k/v: donation is legal
        checksum = pool.k.sum()          # read AFTER the rebind
        return checksum

    def upload_rows(self, rows):
        host = [[float(x) for x in row] for row in rows]
        batch = jnp.asarray(host, jnp.float32)   # one hoisted upload
        ix = jnp.arange(batch.shape[0], dtype=jnp.int32)
        return batch, ix
