"""devmem fixture: every rule violated once."""
import jax
import jax.numpy as jnp
import numpy as np


class Pool:
    def __init__(self, n):
        self.k = jnp.zeros((n, 4))       # memspace: device
        self.v = jnp.zeros((n, 4))       # memspace: device
        self.meta = np.zeros((n,))       # memspace: host

    def adopt(self, k, v):
        self.k = k
        self.v = v


class Engine:
    def __init__(self, pool: Pool):
        self.pool = pool
        donate = (1, 2)
        self._step = jax.jit(lambda p, k, v: (p, k, v),
                             donate_argnums=donate)
        self.params = jnp.zeros((4,))    # memspace: device

    def hot_step(self, pool: Pool):
        # implicit D2H in the hot path (no staging annotation)
        snapshot = np.asarray(self.params)
        logits, new_k, new_v = self._step(self.params, pool.k, pool.v)
        checksum = pool.k.sum()          # use-after-donate: not rebound
        pool.adopt(new_k, new_v)
        return snapshot, checksum

    def upload_rows(self, rows):
        out = []
        for row in rows:
            host_row = [float(x) for x in row]
            out.append(jnp.asarray(host_row))   # H2D inside the loop
        ix = jnp.arange(len(out))               # unpinned index dtype
        return out, ix
