"""Fixture: two locks acquired in opposite orders — a lock-order
cycle the checker must fail on."""
import threading


class Pair:
    def __init__(self):
        self.a = threading.Lock()
        self.b = threading.Lock()

    def ab(self):
        with self.a:
            with self.b:
                pass

    def ba(self):
        with self.b:
            with self.a:
                pass
