"""Fixture: a guarded attribute written without its lock."""
import threading


class Counter:
    def __init__(self):
        self.lock = threading.Lock()
        self.value = 0              # guarded-by: self.lock

    def bump_locked(self):
        with self.lock:
            self.value += 1

    def bump_racy(self):
        self.value += 1             # <- the checker must flag this
