"""Fixture: every guarded access is dominated by its lock (or a
requires contract) — the checker must stay silent."""
import threading


class Counter:
    def __init__(self):
        self.lock = threading.Lock()
        self.value = 0              # guarded-by: self.lock
        self.label = ""             # swap-only

    def bump(self):
        with self.lock:
            self.value += 1
            self._bump_locked()

    # requires: self.lock
    def _bump_locked(self):
        self.value += 1

    def relabel(self, s):
        self.label = s              # whole-reference swap: allowed
