"""Fixture: same shape flow as jit_bad, but the batch size passes
through a bucketing helper first — the checker must stay silent."""
import jax
import jax.numpy as jnp

_PF_QUANTUM = 16


def _round_b(n):
    return ((n + _PF_QUANTUM - 1) // _PF_QUANTUM) * _PF_QUANTUM


def _fn(x):
    return x * 2


_step = jax.jit(_fn, static_argnums=())


def run(tokens):
    n = _round_b(len(tokens))
    x = jnp.zeros((n, 4))
    return _step(x)
