"""Fixture: the same sync as hostsync_bad, but budgeted through the
sibling allow.toml — the run must pass (and the entry count as a
'sync' toward the budget)."""


class Engine:
    def _decode(self):
        return object()             # stands in for a device array

    def _step(self):
        x = self._decode()
        return int(x[0])            # allowlisted in allow.toml
