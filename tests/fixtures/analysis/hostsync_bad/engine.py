"""Fixture: a device->host sync inside the hot path (roots are
passed as Engine._step by the test)."""


class Engine:
    def _decode(self):
        return object()             # stands in for a device array

    def _step(self):
        x = self._decode()
        return int(x[0])            # <- device sync, must be flagged
