"""units fixture: a cost term priced over the wrong channel, and a
seconds/bytes mix-up."""
from dataclasses import dataclass


@dataclass
class Hw:
    hbm_bw: float = 1e12        # unit: bytes/s @hbm
    link_bw: float = 1e10       # unit: bytes/s @link
    host_bw: float = 1e9        # unit: bytes/s @host
    dispatch: float = 1e-4      # unit: s


@dataclass
class Llm:
    param_bytes: float = 1e9    # unit: bytes @weights
    kv_per_tok: float = 1e5     # unit: bytes/token @kv


class Cost:
    def __init__(self, hw: Hw, llm: Llm):
        self.hw = hw
        self.llm = llm

    # unit: tokens=tokens -> s
    def t_migrate(self, tokens):
        kv = self.llm.kv_per_tok * tokens
        # KV bytes move over the LINK, but are priced at host_bw
        return kv / self.hw.host_bw + self.hw.dispatch

    # unit: -> s
    def t_step(self):
        # bytes + seconds: a dimensional mix-up
        return self.llm.param_bytes + self.hw.dispatch
