"""Durable job store (DESIGN.md §12.2): signature journal, kill-tolerant
loading, session resume with zero re-execution, and the hardened
``load_batch_state`` diagnostics."""
import json

import pytest

from benchmarks.common import make_real_processor
from repro.core.consolidate import consolidate
from repro.runtime.coordinator import BatchState
from repro.runtime.jobstore import (CheckpointError, JobStore,
                                    load_batch_state, save_batch_state,
                                    signature_map)
from repro.workloads import build_workload


# ---------------------------------------------------------------------------
# signature map
# ---------------------------------------------------------------------------

def test_signature_map_stable_across_reconsolidation():
    """Re-consolidating the same (template, bindings) yields the SAME
    (query, node) → key map — the property resume rests on."""
    g, bindings, _ = build_workload("wt", 6, seed=0)
    m1 = signature_map(consolidate(g, bindings))
    m2 = signature_map(consolidate(g, bindings))
    assert m1 == m2
    assert set(q for q, _ in m1) == set(range(6))
    # every (query, node) pair the batch serves has a journal key
    assert len(m1) == 6 * len(g.nodes)


def test_signature_map_dedup_shares_keys():
    """Queries with identical bindings share journal keys (dedup
    survives restart); distinct bindings do not."""
    g, bindings, _ = build_workload("wt", 4, seed=0)
    dup = list(bindings) + [bindings[0]]            # query 4 repeats query 0
    m = signature_map(consolidate(g, dup))
    for nid in g.nodes:
        assert m[(4, nid)] == m[(0, nid)]
    assert any(m[(1, nid)] != m[(0, nid)] for nid in g.nodes)


def test_signature_map_sampled_llm_keys_are_per_query():
    """temperature > 0 LLM nodes must never replay across queries."""
    g, bindings, _ = build_workload("wt", 3, seed=0)
    hot = [n.with_(temperature=0.8) if n.is_llm() else n
           for n in g.nodes.values()]
    from repro.core.graphspec import GraphSpec
    g_hot = GraphSpec(g.name, hot, g.edges)
    dup = [bindings[0], bindings[0]]
    m = signature_map(consolidate(g_hot, dup))
    for nid in g_hot.llm_nodes():
        assert m[(0, nid)] != m[(1, nid)]           # sampled: never shared
    for nid in g_hot.tool_nodes():
        assert m[(0, nid)] == m[(1, nid)]           # tools still dedup


# ---------------------------------------------------------------------------
# the journal
# ---------------------------------------------------------------------------

def test_journal_roundtrip_and_fanout_dedup(tmp_path):
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p)
    js.record("k1", "n", "v1")
    js.record("k1", "n", "v1")              # same-run fan-out: one line
    js.record("k2", "n", "v2")
    js.close()
    js2 = JobStore(p)
    assert js2.lookup("k1") == "v1" and js2.lookup("k2") == "v2"
    assert js2.summary()["restored_signatures"] == 2
    # re-recording an at-open key counts as re-execution
    js2.record("k1", "n", "v1")
    assert js2.summary()["re_executed_signatures"] == 1
    js2.close()


def test_journal_torn_tail_dropped(tmp_path):
    """A half-written last line (kill -9 mid-append) is dropped, not
    half-applied; the intact prefix survives."""
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p, fsync_every=1)
    js.record("k1", "n", "v1")
    js.record("k2", "n", "v2")
    js.close()
    with open(p, "a") as f:
        f.write('{"k": "k3", "n": "n", "v": "v3", "c": "tr')     # torn
    js2 = JobStore(p)
    assert js2.lookup("k1") == "v1" and js2.lookup("k3") is None
    assert js2.summary()["dropped_lines"] == 1
    js2.close()


def test_journal_append_after_torn_tail_stays_loadable(tmp_path):
    """Reopening a journal whose tail was torn must truncate the torn
    fragment BEFORE appending: otherwise the next record() concatenates
    onto the fragment, merging into one invalid line that (a) silently
    loses the appended record and (b) once any further line follows,
    makes every later load raise CheckpointError."""
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p, fsync_every=1)
    js.record("k1", "n", "v1")
    js.close()
    with open(p, "a") as f:
        f.write('{"k": "k2", "n": "n", "v": "v2", "c": "tr')     # torn
    js2 = JobStore(p)                       # drops + truncates the tail
    assert js2.summary()["dropped_lines"] == 1
    js2.record("k2", "n", "v2-redone")
    js2.record("k3", "n", "v3")
    js2.close()
    js3 = JobStore(p)                       # second restart: still loads
    assert js3.lookup("k1") == "v1"
    assert js3.lookup("k2") == "v2-redone"  # not merged into the fragment
    assert js3.lookup("k3") == "v3"
    assert js3.summary()["dropped_lines"] == 0
    js3.close()


def test_journal_missing_final_newline_repaired(tmp_path):
    """A valid tail line missing only its terminator gets one written
    before the first appended record, instead of being merged with it."""
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p, fsync_every=1)
    js.record("k1", "n", "v1")
    js.close()
    with open(p, "rb+") as f:
        f.seek(-1, 2)
        f.truncate()                        # strip the trailing "\n"
    js2 = JobStore(p)
    assert js2.lookup("k1") == "v1"         # intact line still restores
    js2.record("k2", "n", "v2")
    js2.close()
    js3 = JobStore(p)
    assert js3.lookup("k1") == "v1" and js3.lookup("k2") == "v2"
    assert js3.summary()["dropped_lines"] == 0
    js3.close()


def test_journal_record_after_close_is_noop(tmp_path):
    """A straggler listener firing after close() must not crash."""
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p)
    js.record("k1", "n", "v1")
    js.close()
    js.record("k2", "n", "v2")              # no-op, no AttributeError
    js.close()                              # idempotent
    js2 = JobStore(p)
    assert js2.lookup("k1") == "v1" and js2.lookup("k2") is None
    js2.close()


def test_journal_mid_file_corruption_raises(tmp_path):
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p)
    js.record("k1", "n", "v1")
    js.close()
    lines = open(p).readlines()
    lines.insert(1, "garbage not json\n")
    with open(p, "w") as f:
        f.writelines(lines)
    with pytest.raises(CheckpointError, match="not the torn tail"):
        JobStore(p)


def test_journal_checksum_guards_value(tmp_path):
    """A bit-flipped value in the tail fails its checksum and is
    dropped rather than restored corrupt."""
    p = str(tmp_path / "j.jsonl")
    js = JobStore(p, fsync_every=1)
    js.record("k1", "n", "v1")
    js.close()
    lines = open(p).readlines()
    entry = json.loads(lines[-1])
    entry["v"] = "tampered"
    lines[-1] = json.dumps(entry) + "\n"
    with open(p, "w") as f:
        f.writelines(lines)
    js2 = JobStore(p)
    assert js2.lookup("k1") is None
    assert js2.summary()["dropped_lines"] == 1
    js2.close()


# ---------------------------------------------------------------------------
# session resume
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_session_resume_zero_reexecution(tmp_path):
    """Run a batch to completion with a jobstore, run it again against
    the same journal: every signature restores, nothing re-executes, no
    decode happens, outputs are bitwise-identical."""
    js = str(tmp_path / "journal.jsonl")

    def run():
        proc, g, cons, bindings, plan = make_real_processor(
            "wt", n=6, workers=2, decode_cap=3, seed=0, jobstore_path=js)
        return proc.run(cons, plan)

    r1 = run()
    s1 = r1.extra["jobstore"]
    assert s1["completed_signatures"] > 0
    assert s1["re_executed_signatures"] == 0

    r2 = run()
    s2 = r2.extra["jobstore"]
    assert s2["re_executed_signatures"] == 0
    assert s2["restored_results"] == 6 * 4          # every (query, node)
    assert r2.extra["decode_tokens"] == 0           # no LLM work re-paid
    assert r1.extra["results"] == r2.extra["results"]


# ---------------------------------------------------------------------------
# load_batch_state hardening (the former runtime.checkpoint API)
# ---------------------------------------------------------------------------

def _state(n=4):
    g, _, _ = build_workload("w+", n, seed=0)
    return g, BatchState(g, n)


def test_load_batch_state_rejects_unknown_node(tmp_path):
    """A checkpoint naming a node the live graph lacks raises with the
    path, the bad node, and a sample of the real graph — and applies
    NOTHING (validate-then-apply)."""
    g, st = _state()
    st.set_result(0, "draft", "r0")
    p = str(tmp_path / "ck.json")
    save_batch_state(st, p)
    payload = json.load(open(p))
    payload["results"].append([1, "no_such_node", "x"])
    json.dump(payload, open(p, "w"))
    fresh = BatchState(g, 4)
    with pytest.raises(CheckpointError) as ei:
        load_batch_state(fresh, p)
    msg = str(ei.value)
    assert "no_such_node" in msg and p in msg and "draft" in msg
    assert "stale checkpoint" in msg
    with fresh.lock:
        assert not fresh.results                    # nothing half-applied


def test_load_batch_state_rejects_non_json(tmp_path):
    g, st = _state()
    p = str(tmp_path / "ck.json")
    with open(p, "w") as f:
        f.write("{truncated")
    with pytest.raises(CheckpointError, match="not valid JSON"):
        load_batch_state(st, p)


def test_load_batch_state_rejects_wrong_shape(tmp_path):
    g, st = _state()
    p = str(tmp_path / "ck.json")
    json.dump({"wrong": 1}, open(p, "w"))
    with pytest.raises(CheckpointError, match="found keys"):
        load_batch_state(st, p)


def test_load_batch_state_rejects_malformed_entry(tmp_path):
    g, st = _state()
    p = str(tmp_path / "ck.json")
    json.dump({"n_queries": 4, "results": [["not-a-triple"]]},
              open(p, "w"))
    with pytest.raises(CheckpointError, match="entry 0"):
        load_batch_state(st, p)


def test_checkpoint_shim_reexports():
    """The old import path keeps working."""
    from repro.runtime import checkpoint
    assert checkpoint.save_batch_state is save_batch_state
    assert checkpoint.load_batch_state is load_batch_state
    assert checkpoint.CheckpointError is CheckpointError
