"""Training substrate: loss descent, checkpoint/elastic-reshard, grad
compression, deterministic data."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.training import (AdamWConfig, DataConfig, SyntheticLMData,
                            TrainerConfig, load_checkpoint, save_checkpoint,
                            train_loop)
from repro.training.checkpoint import latest_checkpoint
from repro.training.grad_compress import (compress_tree, decompress_tree,
                                          init_error_state)
from repro.training.optimizer import adamw_init, cosine_lr


def test_loss_decreases_and_resumes(tmp_path):
    cfg = get_smoke("qwen3-1.7b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      structure=0.9)
    tcfg = TrainerConfig(remat=False, adamw=AdamWConfig(
        lr=1e-3, warmup_steps=3, total_steps=30))
    out = train_loop(cfg, tcfg, dcfg, num_steps=12, ckpt_dir=str(tmp_path),
                     ckpt_every=6, log_every=4)
    assert out["losses"][-1][1] < out["losses"][0][1]
    out2 = train_loop(cfg, tcfg, dcfg, num_steps=14, ckpt_dir=str(tmp_path),
                      ckpt_every=6, log_every=1)
    assert out2["losses"][0][0] >= 12          # resumed, not restarted


def test_checkpoint_roundtrip_exact(tmp_path):
    cfg = get_smoke("xlstm-350m")
    from repro.engine.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    d = save_checkpoint(str(tmp_path), 7, params, opt, extra={"k": 1})
    step, p2, o2, extra = load_checkpoint(d, (params, opt))
    assert step == 7 and extra == {"k": 1}
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_atomicity(tmp_path):
    cfg = get_smoke("qwen3-1.7b")
    from repro.engine.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), 1, params, opt)
    save_checkpoint(str(tmp_path), 2, params, opt)
    latest = latest_checkpoint(str(tmp_path))
    assert latest.endswith("step_000000002")


def test_grad_compress_error_feedback_exact():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)}
    err = init_error_state(g)
    q, s, err2 = compress_tree(g, err)
    deq = decompress_tree(q, s)
    assert q["w"].dtype == jnp.int8
    # dequantized + residual reconstructs the corrected gradient exactly
    np.testing.assert_allclose(np.asarray(deq["w"] + err2["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # 2 rounds: residual shrinks the long-run bias (error feedback works)
    q2, s2, err3 = compress_tree(g, err2)
    deq2 = decompress_tree(q2, s2)
    two_round = np.asarray(deq["w"] + deq2["w"]) / 2
    one_round = np.asarray(deq["w"])
    target = np.asarray(g["w"])
    assert np.abs(two_round - target).mean() <= \
        np.abs(one_round - target).mean() + 1e-7


def test_data_determinism_and_sharding():
    dcfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    data = SyntheticLMData(dcfg)
    a, b = data.batch_at(5), data.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(data.batch_at(6)["tokens"], a["tokens"])
    h0 = data.batch_at(5, host_id=0, num_hosts=2)
    h1 = data.batch_at(5, host_id=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]                 # warmup ascends
    assert lrs[2] >= lrs[3] >= lrs[4]               # cosine descends
    assert lrs[4] >= 0.1 * 1e-3 - 1e-9
