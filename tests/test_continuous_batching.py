"""Continuous-batching engine: mid-decode admission, paged prefix reuse,
copy-on-write safety, page accounting, variable-length batches."""
import time

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.engine.engine import InferenceEngine
from repro.engine.models import build_model


def _wait(cond, timeout=120.0):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() > deadline:
            raise TimeoutError("condition not reached")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# mid-decode admission
# ---------------------------------------------------------------------------

def test_request_joins_running_batch_mid_decode():
    """A request submitted while another decodes joins the running batch;
    both outputs are exactly what one-shot generation produces."""
    cfg = get_smoke("qwen3-1.7b")
    p1 = list(range(10, 18))
    p2 = list(range(60, 66))
    eng = InferenceEngine(cfg, seed=0)
    h1 = eng.submit(p1, max_new_tokens=48)
    _wait(lambda: eng.stats.decode_tokens >= 1)      # p1 is mid-decode
    h2 = eng.submit(p2, max_new_tokens=4)
    o1, o2 = h1.result(), h2.result()

    # engine stats prove the interleave: two admission waves, and both
    # requests were concurrently resident in the decode batch
    assert eng.stats.admission_waves == 2
    assert eng.stats.peak_batch == 2

    ref = InferenceEngine(cfg, seed=0)
    assert o1 == ref.generate([p1], max_new_tokens=48)[0]
    assert o2 == ref.generate([p2], max_new_tokens=4)[0]


def test_variable_length_prompts_share_one_batch():
    """No group-by-length: mixed-length prompts decode in one batch and
    match per-prompt one-shot outputs exactly."""
    cfg = get_smoke("llama3.2-3b")
    prompts = [[7] + list(range(20, 26)),
               [8] + list(range(30, 41)),
               [9, 50, 51]]
    eng = InferenceEngine(cfg, seed=0)
    outs = eng.generate(prompts, max_new_tokens=5)
    assert eng.stats.admission_waves == 1            # one wave, one batch
    assert eng.stats.peak_batch == 3
    ref = InferenceEngine(cfg, seed=0)
    for p, o in zip(prompts, outs):
        assert ref.generate([p], max_new_tokens=5)[0] == o


@pytest.mark.parametrize("arch", ["xlstm-350m", "recurrentgemma-2b"])
def test_dense_row_families_mixed_lengths(arch):
    """Recurrent/hybrid families ride the same scheduler with dense state
    rows (no paged KV) and still admit variable-length prompts."""
    cfg = get_smoke(arch)
    prompts = [list(range(5, 13)), list(range(30, 41)), [2, 3, 4]]
    eng = InferenceEngine(cfg, seed=0, max_seq_len=64)
    outs = eng.generate(prompts, max_new_tokens=3)
    assert eng.kv is None                            # no pages for state rows
    ref = InferenceEngine(cfg, seed=0, max_seq_len=64)
    for p, o in zip(prompts, outs):
        assert ref.generate([p], max_new_tokens=3)[0] == o


# ---------------------------------------------------------------------------
# paged prefix reuse
# ---------------------------------------------------------------------------

def test_paged_prefix_reuse_counts_and_cow_safety():
    """Aliasing a donor's pages (including its partial page) reuses the
    prefix KV exactly; copy-on-write keeps the donor's tokens intact."""
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 20))                     # 10 tokens: 8 + partial 2
    prompts = [prefix + [100], prefix + [101]]
    eng = InferenceEngine(cfg, seed=0, page_size=8)
    outs = eng.generate(prompts, max_new_tokens=6)
    assert eng.stats.tokens_reused == len(prefix)
    assert eng.stats.pages_shared == 2               # one full + one partial
    assert eng.stats.prefix_hits == 1

    # the exact same tokens come out without any sharing machinery
    ref = InferenceEngine(cfg, seed=0, enable_prefix_sharing=False)
    assert ref.generate(prompts, max_new_tokens=6) == outs
    assert ref.stats.tokens_reused == 0


def test_cow_partial_page_never_corrupts_donor():
    """kv-level check through the engine: after a sharer wrote through the
    aliased partial page, the donor's stored KV is bit-identical to a
    run where no sharing ever happened."""
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 20))
    eng = InferenceEngine(cfg, seed=0, page_size=8)
    eng.generate([prefix + [100]], max_new_tokens=4)     # donor, kept warm
    donor_seq = next(iter(eng._warm))
    k_before, v_before = eng.kv.gather(donor_seq)
    k_before, v_before = k_before.copy(), v_before.copy()
    eng.generate([prefix + [101]], max_new_tokens=4)     # aliases + COWs
    assert eng.stats.tokens_reused == len(prefix)
    k_after, v_after = eng.kv.gather(donor_seq)
    np.testing.assert_array_equal(k_before, k_after)
    np.testing.assert_array_equal(v_before, v_after)


def test_pages_all_freed_after_batch_drains():
    cfg = get_smoke("qwen3-1.7b")
    prompts = [list(range(10, 18)), list(range(40, 52)), [3, 4, 5, 6, 7]]

    eng = InferenceEngine(cfg, seed=0, enable_prefix_sharing=False)
    eng.generate(prompts, max_new_tokens=4)
    assert eng.kv is not None and eng.kv.pages_in_use == 0
    assert not eng.kv.sequences

    # with sharing, retired prompts stay warm for reuse — releasing them
    # must return every page
    eng2 = InferenceEngine(cfg, seed=0)
    eng2.generate(prompts, max_new_tokens=4)
    assert eng2.kv.pages_in_use > 0                  # warm donors retained
    eng2.release_warm()
    assert eng2.kv.pages_in_use == 0
    assert not eng2.kv.sequences


def test_paged_cache_is_the_only_kv_store():
    """Every transformer sequence generated lives in (and is drained
    from) the PagedKVCache; there is no dense fallback path."""
    cfg = get_smoke("qwen3-1.7b")
    eng = InferenceEngine(cfg, seed=0, enable_prefix_sharing=False)
    assert eng.model.paged_kv_layout() is not None
    eng.generate([list(range(5, 17))], max_new_tokens=3)
    assert eng.kv is not None
    assert eng.kv.tokens_reused == 0
    # prompt + decoded KV all went through pages: the sequence is gone
    # after retirement and its pages are back on the free list
    assert len(eng.kv.free_pages) == eng.kv.num_pages


# ---------------------------------------------------------------------------
# chunked prefill (model-level hook)
# ---------------------------------------------------------------------------

def test_chunked_prefill_matches_monolithic_prefill():
    import jax
    import jax.numpy as jnp
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.arange(10, 23, dtype=jnp.int32)[None, :]      # (1, 13)
    full_logits, full_cache = model.prefill(params, toks)

    P, T = 7, 32
    _, pre_cache = model.prefill(params, toks[:, :P])
    k_pre, v_pre = model.cache_kv_rows(pre_cache, 0)         # (L, P, H, D)
    L, _, H, D = k_pre.shape
    k_rows = np.zeros((1, L, T, H, D), np.float32)
    v_rows = np.zeros((1, L, T, H, D), np.float32)
    k_rows[0, :, :P] = k_pre
    v_rows[0, :, :P] = v_pre
    view = model.paged_cache_view(k_rows, v_rows, [P])
    logits2, view2 = model.prefill_with_cache(params, toks[:, P:], view)

    np.testing.assert_array_equal(np.asarray(full_logits, np.float32),
                                  np.asarray(logits2, np.float32))
    S = toks.shape[1]
    k_all, _ = model.cache_kv_rows(view2, 0)
    k_ref, _ = model.cache_kv_rows(full_cache, 0)
    np.testing.assert_array_equal(k_ref[:, :S], k_all[:, :S])


# ---------------------------------------------------------------------------
# coalescing across submissions
# ---------------------------------------------------------------------------

def test_duplicate_submission_coalesces_in_flight():
    cfg = get_smoke("llama3.2-3b")
    p = list(range(5, 15))
    eng = InferenceEngine(cfg, seed=0)
    h1 = eng.submit(p, max_new_tokens=32)
    _wait(lambda: eng.stats.decode_tokens >= 1)
    h2 = eng.submit(p, max_new_tokens=32)            # exact duplicate
    assert h1.result() == h2.result()
    assert eng.stats.coalesced_requests == 1
    assert eng.stats.peak_batch == 1                 # follower holds no slot
