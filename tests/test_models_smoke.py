"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness; prefill+decode consistency for each family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.engine.models import build_model

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.ones((B, 8, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.num_patches, cfg.d_model),
                                         jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    if cfg.family == "audio":
        logits, _ = model.forward(params, batch["tokens"], batch["frames"])
    elif cfg.family == "vlm":
        logits, _ = model.forward(params, batch["tokens"],
                                  prefix_embeds=batch["patch_embeds"])
        assert logits.shape[1] == 16 + cfg.num_patches
        logits = logits[:, cfg.num_patches:]
    else:
        logits, _ = model.forward(params, batch["tokens"])
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss = model.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    from repro.training import TrainerConfig, make_train_step
    from repro.training.optimizer import adamw_init
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(RNG)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, TrainerConfig(remat=False)))
    params2, opt2, metrics = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mixtral-8x22b",
                                  "whisper-tiny", "xlstm-350m",
                                  "recurrentgemma-2b"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced forward logits == prefill+decode_step logits.

    MoE capacity clamping is sequence-LENGTH dependent (different lengths
    drop different tokens), so the MoE arch runs effectively dropless
    (high capacity factor) — the test targets the attention/cache path.
    """
    cfg = get_smoke(arch).replace(dtype="float32", param_dtype="float32")
    if cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(RNG)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    if cfg.family == "audio":
        frames = jnp.ones((B, 8, cfg.d_model), jnp.float32)
        full, _ = model.forward(params, toks, frames)
        logits, cache = model.prefill(params, toks[:, :S - 2], frames)
    else:
        full, _ = model.forward(params, toks)
        logits, cache = model.prefill(params, toks[:, :S - 2])
    cache = model.extend_cache(cache, 4)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full[:, S - 3], np.float32),
                               atol=2e-3, rtol=2e-3)
    for t in range(S - 2, S):
        logits, cache = model.decode_step(params, toks[:, t], cache)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   atol=2e-3, rtol=2e-3)


def test_swa_ring_buffer_decode():
    """Mixtral ring-buffer cache stays bounded and finite past the window."""
    cfg = get_smoke("mixtral-8x22b")
    model = build_model(cfg)
    params = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, cfg.swa_window), 0,
                              cfg.vocab_size)
    logits, cache = model.prefill(params, toks)
    assert cache["k"].shape[2] == cfg.swa_window
    for _ in range(4):                      # decode past the window: wraps
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = model.decode_step(params, nxt, cache)
        assert cache["k"].shape[2] == cfg.swa_window
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_chunked_attention_equals_dense():
    from repro.engine.models.layers import attention_xla, attention_xla_chunked
    rng = np.random.default_rng(0)
    B, S, H, Hkv, Dh = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    for window in (0, 16):
        a = attention_xla(q, k, v, q_positions=pos, kv_positions=pos,
                          causal=True, window=window)
        b = attention_xla_chunked(q, k, v, q_positions=pos,
                                  kv_positions=pos, causal=True,
                                  window=window, block_q=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
