"""Dry-run machinery on a small (8-device) mesh — subprocess so the
device count doesn't leak into other tests."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax
from repro.configs import SHAPES, get_smoke, input_specs
from repro.distribution.sharding import (ShardingPolicy, input_shardings,
                                         param_shardings)
from repro.engine.models import build_model
from repro.launch.hlo_cost import analyze_hlo
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.trainer import TrainerConfig, make_train_step

from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((2, 4), ("data", "model"))
cfg = get_smoke("qwen3-8b").replace(d_model=64, d_ff=256, vocab_size=512)
model = build_model(cfg)
pol = ShardingPolicy.for_mesh(mesh)
params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
p_sh = param_shardings(params_shape, mesh, pol)
opt_shape = jax.eval_shape(adamw_init, params_shape)
o_sh = param_shardings(opt_shape, mesh, pol)

step = make_train_step(cfg, TrainerConfig(remat=True,
                                          adamw=AdamWConfig(total_steps=10)))
specs = {"tokens": jax.ShapeDtypeStruct((8, 32), jax.numpy.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jax.numpy.int32)}
from jax.sharding import NamedSharding, PartitionSpec as P
b_sh = {k: NamedSharding(mesh, P("data", None)) for k in specs}
fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
             out_shardings=(p_sh, o_sh, None))
compiled = fn.lower(params_shape, opt_shape, specs).compile()
r = analyze_hlo(compiled.as_text(), score_dims={32})
assert r["flops"] > 0, r
assert compiled.cost_analysis() is not None
print("DRYRUN_OK", r["flops"])
"""


@pytest.mark.multidevice
def test_lower_compile_on_8_device_mesh():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env)
    assert "DRYRUN_OK" in r.stdout, r.stderr[-2000:]
