"""Assigned-architecture config conformance (the table in the brief)."""
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, get_smoke, input_specs
from repro.configs.base import shape_applicable

EXPECTED = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v


def test_arch_details():
    ds = get_config("deepseek-moe-16b")
    assert ds.moe.num_experts == 64 and ds.moe.top_k == 6
    assert ds.moe.num_shared_experts == 2
    mx = get_config("mixtral-8x22b")
    assert mx.moe.num_experts == 8 and mx.moe.top_k == 2
    assert mx.swa_window > 0
    assert get_config("whisper-tiny").enc_layers == 4
    assert get_config("qwen3-8b").qk_norm
    assert get_config("xlstm-350m").block_pattern == ("mlstm", "slstm")
    assert get_config("recurrentgemma-2b").block_pattern == \
        ("rglru", "rglru", "attn")
    assert get_config("internvl2-2b").num_patches == 256


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_same_family(arch):
    full, smoke = get_config(arch), get_smoke(arch)
    assert full.family == smoke.family
    assert (full.moe is None) == (smoke.moe is None)
    assert smoke.param_count() < full.param_count() / 100


def test_long500k_applicability():
    """Sub-quadratic archs run long_500k; pure full-attention archs skip."""
    runs = {a for a in ARCH_IDS
            if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"mixtral-8x22b", "xlstm-350m", "recurrentgemma-2b"}


def test_param_counts_roughly_match_names():
    # analytic counts should be in the ballpark the model names claim
    assert 14e9 < get_config("deepseek-moe-16b").param_count() < 20e9
    assert 120e9 < get_config("mixtral-8x22b").param_count() < 160e9
    assert 60e9 < get_config("deepseek-67b").param_count() < 75e9
    assert 2.5e9 < get_config("llama3.2-3b").param_count() < 4.5e9
    assert 0.25e9 < get_config("xlstm-350m").param_count() < 0.6e9
    # MoE active << total
    ds = get_config("deepseek-moe-16b")
    assert ds.active_param_count() < 0.3 * ds.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_complete(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    kinds = {"train": {"tokens", "labels"}, "prefill": {"tokens"},
             "decode": {"token"}}[SHAPES[shape].kind]
    assert kinds <= set(specs)
    for s in specs.values():
        assert all(d > 0 for d in s.shape)
