"""Session serving (DESIGN.md §10): streaming graft into the running
mega-DAG behind ``ProcessorSession``.

Pins the four session-redesign guarantees:

* a mid-run graft changes WHEN queries run, never WHAT they produce —
  temp-0 outputs are bitwise-identical to the one-shot batch (§10.2);
* grafted queries hit the SHARED signature table — overlapping work is
  deduped across the graft boundary and finished results replay instead
  of re-executing (§10.2);
* ``slo="interactive"`` beats FIFO on TTFT when the batch lane
  saturates the engine (§10.3);
* ``drain()``/``close()`` leak no worker or dispatcher threads (§10.1);

plus the ``ProcessorConfig`` deprecation shim on ``RealProcessor``.
"""
import statistics
import threading
import time

import pytest

from benchmarks.common import smoke_models_for
from repro.runtime import ProcessorConfig, ProcessorSession, RealProcessor
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime


def _session(g, db, **cfg_kw):
    cfg = ProcessorConfig(num_workers=cfg_kw.pop("num_workers", 2),
                          decode_cap=cfg_kw.pop("decode_cap", 3),
                          seed=0, **cfg_kw)
    return ProcessorSession(smoke_models_for(g),
                            ToolRuntime(build_database(db)), config=cfg)


def _normalized(results):
    """{(query, base-node-id): text} — strips the ``t{k}/`` namespace so
    a grafted arm (whose late queries live in a new template slot) is
    comparable to the one-shot arm."""
    out = {}
    for key, val in results.items():
        q, node = key.split(":", 1)
        out[(int(q), node.split("/", 1)[1] if "/" in node else node)] = val
    return out


# ---------------------------------------------------------------------------
def test_graft_bitwise_vs_one_shot():
    """Submitting 4 queries then grafting 2 mid-run produces EXACTLY the
    outputs of submitting all 6 up front (temperature 0)."""
    g, bindings, db = build_workload("wt", 6, seed=0)

    sess = _session(g, db)
    sess.open()
    try:
        sess.submit(g, bindings)
        sess.drain(400)
        rep_one = sess.report()
    finally:
        sess.close()
    assert rep_one.extra["grafts"] == 0

    sess = _session(g, db)
    sess.open()
    try:
        h1 = sess.submit(g, bindings[:4])
        h2 = sess.submit(g, bindings[4:], slo="interactive")
        sess.drain(400)
        rep_graft = sess.report()
    finally:
        sess.close()

    assert rep_graft.extra["grafts"] == 1
    assert all(h.done() and h.exception() is None for h in h1 + h2)
    a, b = _normalized(rep_one.results()), _normalized(rep_graft.results())
    assert a == b and len(a) == 24
    # handles expose the same outputs as the report
    for handle in h2:
        for node, val in handle.result(timeout=5).items():
            base = node.split("/", 1)[1]
            assert b[(handle.query, base)] == val


def test_graft_hits_shared_signature_table():
    """A graft whose bindings repeat in-flight queries dedups against the
    EXISTING signature table: physical tool work is dropped cross-template
    and the grafted queries replay the owners' results bitwise."""
    g, bindings, db = build_workload("wt", 6, seed=0)
    sess = _session(g, db)
    sess.open()
    try:
        sess.submit(g, bindings[:4])
        sess.submit(g, bindings[:2], slo="interactive")   # queries 4,5 == 0,1
        sess.drain(400)
        rep = sess.report()
        summary = sess._cons.cross_template_summary()
    finally:
        sess.close()

    assert summary["cross_template_deduped"] > 0
    assert rep.coalesce_stats["cross_template_merged_tasks"] > 0
    res = rep.results()
    for dup, orig in ((4, 0), (5, 1)):
        for node in ("count", "gen", "verify", "final"):
            assert res[f"{dup}:t1/{node}"] == res[f"{orig}:t0/{node}"]


def test_interactive_ttft_beats_fifo():
    """With the batch lane saturating a single small engine, interactive
    grafts admitted priority-first see lower TTFT than the FIFO control
    (``priority_admission=False``).

    The template is a SINGLE LLM node so the one worker parks right
    after submitting the lane (a tool-dependent successor would block it
    in ``_run_node_pipelined`` and serialize the graft's claim behind
    the whole batch template — then admission order can't matter), and
    the arms share persistent warm hosts so the measured path is pure
    engine scheduling, not per-session JIT retracing."""
    from repro.core.graphspec import GraphSpec, NodeSpec, NodeType
    from repro.runtime.executors import EngineHost
    _, _, db = build_workload("wt", 2, seed=0)
    g = GraphSpec("probe", [NodeSpec(
        id="gen", type=NodeType.LLM, model="qwen3-14b",
        prompt="Summarize topic $topic in detail",
        max_new_tokens=256)], [])           # long decode: the lane must
    bindings = [{"topic": f"subject-{i}"}   # outlive the graft by far
                for i in range(14)]
    models = smoke_models_for(g)
    tools = ToolRuntime(build_database(db))
    hosts = [EngineHost(models, seed=0,
                        engine_kwargs={"max_batch": 2})]

    def arm(priority_admission):
        cfg = ProcessorConfig(num_workers=1, decode_cap=3, seed=0,
                              priority_admission=priority_admission)
        sess = ProcessorSession(models, tools, config=cfg)
        sess.open(hosts=hosts)
        try:
            sess.submit(g, bindings[:12], slo="batch")
            time.sleep(0.05)            # lane admitted, queue backed up
            handles = sess.submit(g, bindings[12:], slo="interactive")
            sess.drain(200)
            rep = sess.report()
            return [h.ttft() for h in handles], rep
        finally:
            sess.close()

    try:
        arm(True)                   # warm each arm's pass shapes once
        arm(False)
        means = None
        for _ in range(3):          # wall-clock compare is load-noisy;
            ttft_prio, rep_prio = arm(True)   # structural checks aren't
            ttft_fifo, rep_fifo = arm(False)
            assert rep_prio.extra["priority_jumps"] > 0
            assert rep_fifo.extra["priority_jumps"] == 0
            assert all(t is not None for t in ttft_prio + ttft_fifo)
            means = (statistics.mean(ttft_prio),
                     statistics.mean(ttft_fifo))
            if means[0] < means[1]:
                break
        else:
            pytest.fail(f"priority TTFT never beat FIFO in 3 runs: "
                        f"prio={means[0]:.3f}s fifo={means[1]:.3f}s")
    finally:
        for h in hosts:
            h.shutdown()


def test_session_close_leaks_no_threads():
    before = set(threading.enumerate())
    g, bindings, db = build_workload("wt", 4, seed=0)
    sess = _session(g, db)
    sess.open()
    try:
        handles = sess.submit(g, bindings[:2])
        sess.submit(g, bindings[2:])
        sess.drain(400)
        assert all(h.done() for h in handles)
    finally:
        sess.close()
    sess.close()                            # idempotent
    leaked = [t for t in set(threading.enumerate()) - before if t.is_alive()]
    assert not leaked, f"session leaked threads: {leaked}"


def test_close_clean_after_worker_death_mid_drain():
    """A worker dying mid-batch (engine blows up under it) fails drain()
    with the real error — and close() still joins every thread, twice."""
    before = set(threading.enumerate())
    g, bindings, db = build_workload("wt", 4, seed=0)
    sess = _session(g, db)
    sess.open()

    def _explode(model):
        raise RuntimeError("injected engine failure")
    for host in sess.hosts:                 # whichever worker claims first
        host.engine_for = _explode
    try:
        sess.submit(g, bindings)
        with pytest.raises(RuntimeError, match="injected engine failure"):
            sess.drain(120)
    finally:
        sess.close()
    sess.close()                            # idempotent after failure
    leaked = [t for t in set(threading.enumerate()) - before if t.is_alive()]
    assert not leaked, f"failed session leaked threads: {leaked}"


def test_close_clean_when_submit_rejects():
    """A submit() that raises before bootstrap leaves nothing running:
    close() is clean and idempotent, and later submits are refused."""
    before = set(threading.enumerate())
    g, bindings, db = build_workload("wt", 2, seed=0)
    sess = _session(g, db)
    sess.open()
    with pytest.raises(ValueError, match="unknown SLO class"):
        sess.submit(g, bindings, slo="no-such-lane")
    sess.close()
    sess.close()
    leaked = [t for t in set(threading.enumerate()) - before if t.is_alive()]
    assert not leaked, f"never-started session leaked threads: {leaked}"
    with pytest.raises(RuntimeError, match="closed"):
        sess.submit(g, bindings)


def test_processor_config_shim():
    """Loose RealProcessor kwargs still work for one release behind a
    DeprecationWarning; unknown names raise immediately."""
    g, _, db = build_workload("wt", 2, seed=0)
    models = smoke_models_for(g)
    tools = ToolRuntime(build_database(db))

    with pytest.warns(DeprecationWarning):
        proc = RealProcessor(g, models, tools, num_workers=3, decode_cap=5)
    assert proc.config.num_workers == 3 and proc.W == 3
    assert proc.config.decode_cap == 5

    with pytest.raises(TypeError, match="unknown RealProcessor arguments"):
        RealProcessor(g, models, tools, worker_count=3)

    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # config path must NOT warn
        proc = RealProcessor(g, models, tools,
                             config=ProcessorConfig(num_workers=2))
    assert proc.config.num_workers == 2
