import os
import sys

# tests must see ONE cpu device (the dry-run sets 512 in its own process)
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
