"""Deterministic fault injection (DESIGN.md §12.3): seeded chaos runs
must complete bitwise-identically to clean runs by riding the existing
recovery machinery — dispatcher retries for tool faults, PlanBoard
overflow for worker loss, ordinary scheduling for engine delays.

``REPRO_FAULT_SEED`` (the CI chaos matrix variable) picks the seed;
unset defaults to 1 so the test is deterministic locally too.
"""
import os
import threading

import pytest

from benchmarks.common import smoke_models_for
from repro.runtime import (FaultInjector, FaultPlan, ProcessorConfig,
                           ProcessorSession, TransientToolError)
from repro.workloads import build_workload
from repro.workloads.datagen import build_database
from repro.workloads.tools import ToolRuntime

SEED = int(os.environ.get("REPRO_FAULT_SEED", "1"))


def _run(g, db, bindings, **cfg_kw):
    """(report, dead-worker set) for one full session run."""
    cfg = ProcessorConfig(num_workers=2, decode_cap=3, seed=0, **cfg_kw)
    sess = ProcessorSession(smoke_models_for(g),
                            ToolRuntime(build_database(db)), config=cfg)
    sess.open()
    try:
        sess.submit(g, bindings)
        sess.drain(400)
        rep = sess.report()
        with sess.board.lock:
            dead = set(sess.board.dead)
    finally:
        sess.close()
    return rep, dead


# ---------------------------------------------------------------------------
# plan / injector plumbing
# ---------------------------------------------------------------------------

def test_fault_plan_from_env():
    env = {"REPRO_FAULT_SEED": "7", "REPRO_FAULT_TOOL_RATE": "0.25",
           "REPRO_FAULT_KILL": "0:1, 2:3",
           "REPRO_FAULT_DELAY_S": "0.05", "REPRO_FAULT_DELAY_RATE": "0.5"}
    p = FaultPlan.from_env(env)
    assert p.seed == 7 and p.tool_fail_rate == 0.25
    assert p.kill_worker == {0: 1, 2: 3}
    assert p.engine_delay_s == 0.05 and p.engine_delay_rate == 0.5
    assert FaultPlan.from_env({}) is None           # injection off
    with pytest.raises(ValueError, match="REPRO_FAULT_KILL"):
        FaultPlan.from_env({"REPRO_FAULT_SEED": "1",
                            "REPRO_FAULT_KILL": "zero:one"})


def test_injector_rolls_deterministic():
    """Same plan → same decisions at the same sites, independent of
    call interleaving (what makes chaos runs reproducible)."""
    plan = FaultPlan(seed=SEED, tool_fail_rate=0.5)
    a, b = FaultInjector(plan), FaultInjector(plan)
    sites = [f"sql|q{i}" for i in range(64)]
    rolls_a = [a._roll("tool", s) for s in sites]
    rolls_b = [b._roll("tool", s) for s in reversed(sites)]
    assert rolls_a == list(reversed(rolls_b))
    other = FaultInjector(FaultPlan(seed=SEED + 1, tool_fail_rate=0.5))
    assert rolls_a != [other._roll("tool", s) for s in sites]


def test_injector_bounds_failures_per_signature():
    """An unlucky signature fails at most ``max_tool_failures`` times;
    later attempts always pass (retries are guaranteed to converge)."""
    inj = FaultInjector(FaultPlan(seed=SEED, tool_fail_rate=1.0,
                                  max_tool_failures=2))
    fails = 0
    for _ in range(5):
        try:
            inj.tool_call("sig-x", "sql")
        except TransientToolError:
            fails += 1
    assert fails == 2
    assert inj.summary()["tool_faults_injected"] == 2


# ---------------------------------------------------------------------------
# chaos runs (real engines, tiny models)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tool_faults_recover_via_retry():
    """High injected tool-failure rate, retries > max failures: the run
    completes with outputs bitwise-identical to the clean run."""
    g, bindings, db = build_workload("wt", 6, seed=0)
    clean, _ = _run(g, db, bindings)
    plan = FaultPlan(seed=SEED, tool_fail_rate=0.9, max_tool_failures=2)
    rep, _ = _run(g, db, bindings, faults=plan, tool_retries=3)
    assert rep.extra["faults"]["tool_faults_injected"] > 0
    assert rep.extra["tool_retries"] > 0
    assert rep.extra["results"] == clean.extra["results"]


@pytest.mark.slow
def test_worker_loss_mid_epoch_recovers():
    """Worker 0 dies after its first node: the survivor absorbs the
    overflow — no hang, no dropped queries, bitwise-identical outputs."""
    g, bindings, db = build_workload("wt", 6, seed=0)
    clean, _ = _run(g, db, bindings)
    plan = FaultPlan(seed=SEED, kill_worker={0: 1})
    rep, dead = _run(g, db, bindings, faults=plan)
    assert dead == {0}                      # the kill really happened
    assert rep.extra["results"] == clean.extra["results"]
    assert len(rep.extra["results"]) == 6 * len(g.nodes)


@pytest.mark.slow
def test_engine_delays_perturb_not_corrupt():
    """Injected engine stalls shift timing only: outputs match the
    clean run exactly."""
    g, bindings, db = build_workload("wt", 6, seed=0)
    clean, _ = _run(g, db, bindings)
    plan = FaultPlan(seed=SEED, engine_delay_s=0.05, engine_delay_rate=1.0)
    rep, _ = _run(g, db, bindings, faults=plan)
    assert rep.extra["faults"]["engine_delays_injected"] > 0
    assert rep.extra["results"] == clean.extra["results"]


@pytest.mark.slow
def test_retry_exhaustion_surfaces_cleanly():
    """When failures outlast the retry budget the error surfaces from
    drain() — and close() still leaks no threads."""
    before = set(threading.enumerate())
    g, bindings, db = build_workload("wt", 4, seed=0)
    plan = FaultPlan(seed=SEED, tool_fail_rate=1.0, max_tool_failures=10)
    cfg = ProcessorConfig(num_workers=2, decode_cap=3, seed=0,
                          faults=plan, tool_retries=1)
    sess = ProcessorSession(smoke_models_for(g),
                            ToolRuntime(build_database(db)), config=cfg)
    sess.open()
    try:
        sess.submit(g, bindings)
        with pytest.raises(TransientToolError):
            sess.drain(120)
    finally:
        sess.close()
    sess.close()                            # idempotent after failure
    leaked = [t for t in set(threading.enumerate()) - before if t.is_alive()]
    assert not leaked, f"failed session leaked threads: {leaked}"
