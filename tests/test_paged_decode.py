"""Device-resident paged decode: bitwise identity with the dense-view
reference path (fresh / warm / mid-batch admission / COW-shared partial
pages), page-scatter append round trips, host<->device traffic
acceptance, grace-window admission and claim throttling.

Fast suite: tiny configs, n<=3 queries, decode_cap<=3 for e2e runs.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.engine.engine import InferenceEngine
from repro.engine.kvcache import PagedKVCache


def _wait(cond, timeout=120.0):
    deadline = time.monotonic() + timeout
    while not cond():
        if time.monotonic() > deadline:
            raise TimeoutError("condition not reached")
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# paged kernel path vs dense-view path: bitwise-identical outputs
# ---------------------------------------------------------------------------

def test_paged_vs_dense_view_identity_fresh_and_warm():
    """Fresh prompts, then a warm re-run aliasing the first run's pages
    (including a COW-shared NON-ALIGNED partial page): token outputs are
    identical on both decode paths, and the paged path never
    materializes a dense view."""
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 20))                 # 10 tokens: full + partial
    prompts = [prefix + [100], prefix + [101], list(range(40, 47))]
    outs = {}
    for paged in (True, False):
        eng = InferenceEngine(cfg, seed=0, page_size=8, paged_decode=paged)
        try:
            first = eng.generate(prompts, max_new_tokens=4)
            again = eng.generate(prompts, max_new_tokens=4)   # warm aliases
            assert eng.stats.prefix_hits >= 1
            assert eng.stats.tokens_reused >= len(prefix)
            outs[paged] = (first, again)
            if paged:
                assert eng.stats.view_rebuilds == 0
            else:
                assert eng.stats.view_rebuilds > 0
        finally:
            eng.shutdown()
    assert outs[True] == outs[False]
    assert outs[True][0] == outs[True][1]        # warm run bitwise stable


def test_paged_vs_dense_view_identity_mid_batch_admission():
    cfg = get_smoke("llama3.2-3b")
    p1, p2 = list(range(10, 18)), list(range(60, 66))
    outs = {}
    for paged in (True, False):
        eng = InferenceEngine(cfg, seed=0, paged_decode=paged)
        try:
            h1 = eng.submit(p1, max_new_tokens=24)
            _wait(lambda: eng.stats.decode_tokens >= 1)
            h2 = eng.submit(p2, max_new_tokens=4)
            outs[paged] = (h1.result(), h2.result())
            assert eng.stats.peak_batch == 2
        finally:
            eng.shutdown()
    assert outs[True] == outs[False]


def test_paged_engine_frees_pages_and_preserves_donor_after_cow():
    """COW safety through the paged decode path: the donor's stored KV
    is untouched after a sharer wrote through the aliased partial page,
    and releasing the warm set returns every page."""
    cfg = get_smoke("qwen3-1.7b")
    prefix = list(range(10, 20))
    eng = InferenceEngine(cfg, seed=0, page_size=8)
    try:
        eng.generate([prefix + [100]], max_new_tokens=4)
        donor_seq = next(iter(eng._warm))
        k_before, v_before = eng.kv.gather(donor_seq)
        k_before, v_before = np.asarray(k_before), np.asarray(v_before)
        eng.generate([prefix + [101]], max_new_tokens=4)    # aliases + COWs
        assert eng.stats.tokens_reused == len(prefix)
        k_after, v_after = eng.kv.gather(donor_seq)
        np.testing.assert_array_equal(k_before, np.asarray(k_after))
        np.testing.assert_array_equal(v_before, np.asarray(v_after))
        eng.release_warm()
        assert eng.kv.pages_in_use == 0 and not eng.kv.sequences
    finally:
        eng.shutdown()


# ---------------------------------------------------------------------------
# model level: paged step's Pallas kernel == its XLA gather fallback
# ---------------------------------------------------------------------------

def test_paged_decode_step_kernel_matches_xla_gather():
    """paged_decode_step under the paged Pallas kernel (interpret mode)
    matches the on-device-gather XLA fallback: logits and the scattered
    pool agree to fp tolerance (layers past the first see the previous
    layer's attention output, so bitwise equality is not expected)."""
    from repro.engine.models import build_model
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.arange(10, 21, dtype=jnp.int32)[None, :]    # 11 tokens
    S = prompt.shape[1]
    _, cache = model.prefill(params, prompt)
    layers, heads, dh = model.paged_kv_layout()
    kv = PagedKVCache(layers, num_pages=8, page_size=8, kv_heads=heads,
                      head_dim=dh)
    seq = kv.add_sequence(*model.cache_kv_rows_dev(cache, 0, S))
    kv.prepare_append(seq)
    pt = jnp.asarray([kv.page_table(seq)], jnp.int32)
    lens = jnp.asarray([S], jnp.int32)
    token = jnp.asarray([42], jnp.int32)
    lg_x, kx, vx = model.paged_decode_step(params, token, kv.k, kv.v,
                                           pt, lens, impl="xla")
    lg_p, kp, vp = model.paged_decode_step(params, token, kv.k, kv.v,
                                           pt, lens,
                                           impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(lg_p, np.float32),
                               np.asarray(lg_x, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(kx), np.asarray(kp),
                               atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(vx), np.asarray(vp),
                               atol=5e-2, rtol=5e-2)


def test_engine_kernel_variants_bitwise_identical():
    """The full engine under each Pallas paged-decode kernel variant —
    single-page, multi-page blocked, and fused append+attend (which
    skips the separate scatter dispatch) — emits IDENTICAL tokens.
    Prompts exercise COW-aliased partial pages via the shared prefix."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke("qwen3-1.7b"),
                              attention_impl="pallas_interpret")
    prefix = list(range(10, 20))
    prompts = [prefix + [100], prefix + [101], list(range(40, 47))]
    outs = {}
    for variant in ("single", "blocked", "fused"):
        eng = InferenceEngine(cfg, seed=0, page_size=8, paged_decode=True,
                              kernel_variant=variant)
        try:
            first = eng.generate(prompts, max_new_tokens=5)
            again = eng.generate(prompts, max_new_tokens=5)  # warm aliases
            outs[variant] = (first, again)
        finally:
            eng.shutdown()
    assert outs["single"] == outs["blocked"] == outs["fused"]


# ---------------------------------------------------------------------------
# cache level: in-jit page scatter == append_token, device pool round trip
# ---------------------------------------------------------------------------

def test_page_scatter_append_round_trip_matches_append_token():
    """The batched (page, offset) scatter the decode step uses writes
    the same pool state as the per-token append_token loop — including
    across page boundaries and a COW'd shared partial page."""
    rng = np.random.default_rng(0)
    k0 = rng.standard_normal((2, 6, 2, 8)).astype(np.float32)
    v0 = rng.standard_normal((2, 6, 2, 8)).astype(np.float32)

    def fresh():
        pc = PagedKVCache(num_layers=2, num_pages=16, page_size=4,
                          kv_heads=2, head_dim=8)
        a = pc.add_sequence(k0, v0)                      # 6 tokens: partial
        b = pc.add_sequence(shared_from=a, shared_len=6)  # aliases partial
        return pc, a, b

    steps = [(rng.standard_normal((2, 2, 2, 8)).astype(np.float32),
              rng.standard_normal((2, 2, 2, 8)).astype(np.float32))
             for _ in range(5)]                          # crosses a boundary

    ref, a1, b1 = fresh()
    for k_t, v_t in steps:
        ref.append_token(a1, k_t[:, 0], v_t[:, 0])
        ref.append_token(b1, k_t[:, 1], v_t[:, 1])

    dev, a2, b2 = fresh()
    for k_t, v_t in steps:
        # the decode-step shape: metadata prep, one scatter, commit
        pages, slots = zip(*(dev.prepare_append(s) for s in (a2, b2)))
        pi, si = jnp.asarray(pages), jnp.asarray(slots)
        dev.k = dev.k.at[:, pi, si].set(jnp.asarray(k_t))
        dev.v = dev.v.at[:, pi, si].set(jnp.asarray(v_t))
        dev.commit_append(a2)
        dev.commit_append(b2)

    for s_ref, s_dev in ((a1, a2), (b1, b2)):
        kr, vr = ref.gather(s_ref)
        kd, vd = dev.gather(s_dev)
        np.testing.assert_array_equal(np.asarray(kr), np.asarray(kd))
        np.testing.assert_array_equal(np.asarray(vr), np.asarray(vd))
    assert ref.pages_in_use == dev.pages_in_use


# ---------------------------------------------------------------------------
# e2e acceptance: O(batch) per-step traffic, not O(batch x seq_len)
# ---------------------------------------------------------------------------

def test_paged_ab_kills_host_gather_traffic():
    """Warm WT A/B: paged decode moves >=10x fewer host<->device bytes
    than the dense-view path, rebuilds no views, and produces identical
    temperature-0 outputs."""
    from benchmarks.common import run_paged_ab
    rep_p, rep_d = run_paged_ab("wt", n=3, workers=2, decode_cap=3)
    assert rep_p.results() == rep_d.results()
    assert rep_p.extra["view_rebuilds"] == 0
    assert rep_d.extra["view_rebuilds"] > 0
    paged_traffic = rep_p.extra["h2d_bytes"] + rep_p.extra["d2h_bytes"]
    dense_traffic = rep_d.extra["h2d_bytes"] + rep_d.extra["d2h_bytes"]
    assert paged_traffic > 0                     # honest accounting
    assert dense_traffic >= 10 * paged_traffic


# ---------------------------------------------------------------------------
# grace-window admission
# ---------------------------------------------------------------------------

def test_admission_window_batches_staggered_arrivals():
    """With a grace window, a burst of staggered submissions forms ONE
    admission wave (one batch shape); outputs are unchanged."""
    cfg = get_smoke("qwen3-1.7b")
    prompts = [list(range(10, 18)), list(range(30, 41)), [3, 4, 5, 6]]
    eng = InferenceEngine(cfg, seed=0, admission_window=0.05)
    try:
        handles = []
        for p in prompts:                        # staggered inside window
            handles.append(eng.submit(p, max_new_tokens=4))
            time.sleep(0.01)
        outs = [h.result() for h in handles]
        assert eng.stats.admission_waves == 1
        assert eng.stats.peak_batch == 3
    finally:
        eng.shutdown()
    ref = InferenceEngine(cfg, seed=0)
    try:
        assert ref.generate(prompts, max_new_tokens=4) == outs
    finally:
        ref.shutdown()


# ---------------------------------------------------------------------------
# claim throttling keeps the replanning window open
# ---------------------------------------------------------------------------

def test_claim_throttling_lets_drift_replan_fire_late():
    """With claim_ahead=1 a worker cannot race ahead and claim the whole
    chain at admission, so a splice queued AFTER the first node's
    results land still finds unclaimed work to re-place — and outputs
    match an unthrottled control run."""
    from benchmarks.common import (make_cm, make_real_processor,
                                   swapped_tail)
    from repro.runtime import OnlineOptimizer

    proc, g, cons, _, plan = make_real_processor(
        "w+", 2, 2, 2, kv_migration=False, claim_ahead=1)
    opt = OnlineOptimizer(make_cm(g, cons), drift_threshold=1e9)
    done = threading.Event()
    report = {}

    def _run():
        try:
            report["rep"] = proc.run(cons, plan, optimizer=opt)
        finally:
            done.set()

    t = threading.Thread(target=_run, daemon=True)
    t.start()
    # queue the forced splice once the run is underway but long before
    # the chain's first node completes (its first-run JIT compile alone
    # takes far longer than this) — with claim_ahead=1 the two
    # downstream nodes are provably still unclaimed at that point,
    # whereas unthrottled workers claim the whole chain at admission
    time.sleep(0.5)
    assert not done.is_set()
    opt.queue_splice(swapped_tail(plan, g, 2))
    assert done.wait(timeout=300.0)
    rep = report["rep"]
    assert rep.extra["plan_splices"] >= 1         # window survived
    assert rep.extra["replans"] >= 1

    ctrl, _, cons2, _, plan2 = make_real_processor(
        "w+", 2, 2, 2, kv_migration=False)
    rep2 = ctrl.run(cons2, plan2)
    assert rep.results() == rep2.results()
